"""Async tiered serving: threaded prefetch double buffer, device-resident
warm cache, and planner-driven tier auto-tuning.

Covers the PR-2 acceptance contract: thread lifecycle (start/stop/
exception propagation), double-buffer correctness under adversarial
stage/consume interleavings, bit-exactness of async mode and of the
device-backed warm cache vs the dense gather path, monotonicity of
`plan_tier_capacities()` in the byte budget, and the serving layer's
async refresh driver + overlap stats.
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern, plan_tier_capacities)
from repro.data import DLRMQueryStream
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import (AsyncPrefetcher, DeviceWarmCache, ParameterServer,
                      PSConfig, StagedBatch, WarmCache)
from repro.serving import BatcherConfig, InferenceServer, Query

ROWS, TABLES, DIM, POOL = 256, 4, 32, 6


def _tables(rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return rng.normal(size=(TABLES, ROWS, DIM)).astype(np.float32)


def _med_pats(rows=ROWS):
    return [make_pattern("med_hot", rows, seed=t) for t in range(TABLES)]


def _batch(pats, batch, pooling, seed):
    return np.stack([p.sample(batch, pooling, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _dense(tables, idx):
    return tables[np.arange(tables.shape[0])[None, :, None], idx]


def _payload(rows):
    """Deterministic fake resolver payload: row id broadcast over DIM."""
    return np.repeat(rows.astype(np.float32)[:, None], 4, axis=1)


def _sb(tag, rows):
    """A staged batch whose indices are a unique [1,1,1] tag."""
    return StagedBatch(np.full((1, 1, 1), tag, np.int32),
                       {0: np.asarray(rows, np.int64)}, {})


# ---------------------------------------------------------------------------
# AsyncPrefetcher: thread lifecycle
# ---------------------------------------------------------------------------

def test_async_prefetcher_start_stop_idempotent():
    pf = AsyncPrefetcher(2, lambda t, rows: _payload(rows))
    assert pf._thread.is_alive()
    pf.close()
    assert not pf._thread.is_alive()
    pf.close()                                   # idempotent
    # the can_stage-then-stage guard must keep working after close
    assert not pf.can_stage()
    with pytest.raises(RuntimeError, match="closed"):
        pf.stage(_sb(0, [1, 2]))


def test_async_prefetcher_worker_exception_degrades_then_raises_once():
    calls = []

    def resolver(t, rows):
        calls.append(t)
        if len(calls) == 1:
            raise ValueError("cold store on fire")
        return _payload(rows)

    pf = AsyncPrefetcher(2, resolver)
    batch = _sb(7, [1, 2, 3])
    assert pf.stage(batch)
    # a failed buffer is dropped, not raised: the lookup falls back to a
    # direct cold gather and stays correct
    assert pf.consume(batch.indices) is None
    # ...and the failed job must be dequeued — an error must not pin a
    # queue slot and starve future staging (regression)
    assert len(pf) == 0 and pf.can_stage()
    # the failure surfaces exactly once, on the next stage(), chained to
    # the original exception
    with pytest.raises(RuntimeError, match="prefetch worker") as ei:
        pf.stage(_sb(8, [4]))
    assert isinstance(ei.value.__cause__, ValueError)
    # after the one report, staging works again
    b = _sb(9, [5])
    assert pf.stage(b)
    got = pf.consume(b.indices)
    np.testing.assert_array_equal(got.data[0], _payload(np.array([5])))
    pf.close()


def test_async_prefetcher_close_surfaces_unreported_error():
    """An error nobody staged over must raise at close(), not vanish."""
    def resolver(t, rows):
        raise ValueError("boom")

    pf = AsyncPrefetcher(2, resolver)
    b = _sb(1, [1])
    assert pf.stage(b)
    assert pf.consume(b.indices) is None         # degrade path, no raise
    with pytest.raises(RuntimeError, match="prefetch worker"):
        pf.close()
    pf.close()                                   # idempotent, no re-raise


def test_async_prefetcher_stage_error_raised_on_next_call():
    def resolver(t, rows):
        raise ValueError("boom")

    pf = AsyncPrefetcher(2, resolver)
    assert pf.stage(_sb(1, [4]))
    deadline = time.perf_counter() + 5.0
    while pf._error is None and time.perf_counter() < deadline:
        time.sleep(0.005)                        # let the worker fail it
    with pytest.raises(RuntimeError, match="prefetch worker"):
        pf.stage(_sb(2, [5]))
    pf.close()


# ---------------------------------------------------------------------------
# AsyncPrefetcher: double-buffer ownership under adversarial interleavings
# ---------------------------------------------------------------------------

def test_async_consume_paths_ready_wait_and_inline():
    """Exercise all three consume paths: READY (full overlap), RUNNING
    (consumer waits on the buffer), PENDING (consumer claims the job and
    resolves inline)."""
    gate = threading.Event()
    resolved_by = []

    def resolver(t, rows):
        name = threading.current_thread().name
        resolved_by.append(name)
        # only the worker blocks on the gate; an inline (consumer-thread)
        # resolution must run immediately
        if name.startswith("ps-async-prefetch") and not gate.is_set():
            assert gate.wait(timeout=10.0)
        return _payload(rows)

    pf = AsyncPrefetcher(3, resolver)
    b1, b2 = _sb(1, [1, 2]), _sb(2, [3])
    assert pf.stage(b1)                          # worker picks it, blocks
    deadline = time.perf_counter() + 5.0
    while not resolved_by and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert pf.stage(b2)                          # stays PENDING
    # PENDING path: worker is stuck on b1, so the consumer claims b2
    got2 = pf.consume(b2.indices)
    assert not got2.ready_at_consume
    np.testing.assert_array_equal(got2.data[0], _payload(np.array([3])))
    assert "ps-async-prefetch" not in resolved_by[-1]
    # RUNNING path: release the gate while the consumer waits on b1
    threading.Timer(0.05, gate.set).start()
    got1 = pf.consume(b1.indices)
    assert not got1.ready_at_consume
    np.testing.assert_array_equal(got1.data[0], _payload(np.array([1, 2])))
    # READY path: stage, wait until the buffer's ready event is actually
    # set (not just until the resolver started), then consume
    b3 = _sb(3, [9])
    assert pf.stage(b3)
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline:
        with pf._cv:
            jobs = list(pf._jobs)
        if jobs and jobs[-1].ready.is_set():
            break
        time.sleep(0.005)
    got3 = pf.consume(b3.indices)
    assert got3.ready_at_consume
    st = pf.stats()
    assert st["consume_waited"] == 2 and st["consume_ready"] == 1
    pf.close()


def test_async_backpressure_and_flush_mid_flight():
    gate = threading.Event()

    def resolver(t, rows):
        if not gate.is_set():
            assert gate.wait(timeout=10.0)
        return _payload(rows)

    pf = AsyncPrefetcher(2, resolver)
    assert pf.stage(_sb(1, [1]))                 # RUNNING (blocked)
    assert pf.stage(_sb(2, [2]))                 # PENDING
    assert not pf.can_stage()
    assert not pf.stage(_sb(3, [3]))             # backpressure: full
    pf.flush()                                   # cancel everything
    assert len(pf) == 0
    gate.set()
    # flushed batches are unreachable; new traffic proceeds normally
    assert pf.consume(_sb(1, [1]).indices) is None
    b4 = _sb(4, [4])
    assert pf.stage(b4)
    got = pf.consume(b4.indices)
    np.testing.assert_array_equal(got.data[0], _payload(np.array([4])))
    pf.close()


def test_async_consume_with_mixed_shape_batches_queued():
    """Out-of-order consume while differently-shaped batches share the queue.

    Regression: `_Job` used the generated dataclass `__eq__`, so
    `deque.remove()` in consume() compared StagedBatch ndarray fields and
    broadcast (32, T, L) against (16, T, L) — exactly what the SLO ladder's
    batch-shrink rung produces mid-stream. Jobs must be identity objects."""
    gate = threading.Event()

    def resolver(t, rows):
        # hold the worker so both jobs stay queued until we consume; an
        # inline (consumer-thread) resolution must proceed immediately
        name = threading.current_thread().name
        if name.startswith("ps-async-prefetch") and not gate.is_set():
            assert gate.wait(timeout=10.0)
        return _payload(rows)

    pf = AsyncPrefetcher(3, resolver)
    big = StagedBatch(np.zeros((4, 2, 3), np.int32),
                      {0: np.asarray([1, 2], np.int64)}, {})
    small = StagedBatch(np.ones((2, 2, 3), np.int32),
                        {0: np.asarray([3], np.int64)}, {})
    assert pf.stage(big)                         # RUNNING (worker blocked)
    assert pf.stage(small)                       # PENDING, behind `big`
    # consuming `small` first forces remove() to walk past the
    # differently-shaped `big` job — must not broadcast-compare
    got_small = pf.consume(small.indices)
    np.testing.assert_array_equal(got_small.data[0],
                                  _payload(np.array([3])))
    gate.set()
    got_big = pf.consume(big.indices)
    np.testing.assert_array_equal(got_big.data[0],
                                  _payload(np.array([1, 2])))
    assert len(pf) == 0
    pf.close()


def test_async_ps_bit_exact_under_adversarial_interleavings():
    """Random stage/lookup/flush/refresh schedules: async lookups must stay
    bit-identical to the dense gather whatever the double buffer is doing."""
    tables = _tables()
    pats = _med_pats()
    rng = np.random.default_rng(42)
    with ParameterServer(
            tables, PSConfig(hot_rows=24, warm_slots=24, prefetch_depth=2,
                             async_prefetch=True, window_batches=4),
            trace=_batch(pats, 16, POOL, seed=0)) as ps:
        for step in range(1, 40):
            op = rng.integers(0, 10)
            if op < 5:                           # stage some future batch
                ps.stage(_batch(pats, 8, POOL, seed=int(rng.integers(50))))
            elif op == 5:
                ps.flush()
            elif op == 6:
                ps.refresh()
            idx = _batch(pats, 8, POOL, seed=int(rng.integers(50)))
            got = ps.lookup(idx)
            assert np.array_equal(got, _dense(tables, idx)), step
        st = ps.stats()
        assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
                == st["total_accesses"])


def test_async_matches_sync_stats_and_values():
    tables = _tables()
    pats = _med_pats()

    def run(async_prefetch):
        ps = ParameterServer(
            tables, PSConfig(hot_rows=32, warm_slots=32, prefetch_depth=2,
                             async_prefetch=async_prefetch),
            trace=_batch(pats, 16, POOL, seed=0))
        outs = []
        for s in range(1, 8):
            ps.stage(_batch(pats, 8, POOL, seed=s + 1))
            outs.append(ps.lookup(_batch(pats, 8, POOL, seed=s)))
            if s == 4:
                ps.refresh()
        st = ps.stats()
        ps.close()
        return np.stack(outs), st

    out_s, st_s = run(False)
    out_a, st_a = run(True)
    assert np.array_equal(out_s, out_a)          # bit-exact across modes
    # identical traffic => identical tier + staging counters; only the
    # async-only wait/overlap counters may differ
    for k in ("total_accesses", "hot_hits", "warm_hits", "cold_misses",
              "prefetch_hits", "prefetch_misses", "staged_rows"):
        assert st_s[k] == st_a[k], k
    assert "consume_overlap_frac" in st_a and "consume_ready" in st_a


# ---------------------------------------------------------------------------
# Device-resident warm cache
# ---------------------------------------------------------------------------

def test_device_warm_cache_payload_is_jax_and_matches_host():
    """Same admission/eviction stream through host and device backings:
    identical tag stores, identical (bit-exact) payload reads, and the
    device payload actually lives in a jax.Array."""
    rng = np.random.default_rng(0)
    host = WarmCache(6, DIM, "lfu")
    dev = DeviceWarmCache(6, DIM, "lfu")
    assert isinstance(dev.data, jax.Array)
    for step in range(12):
        n = int(rng.integers(1, 5))
        rows = rng.choice(64, size=n, replace=False).astype(np.int64)
        payload = rng.normal(size=(n, DIM)).astype(np.float32)
        counts = rng.integers(1, 9, size=n)
        for c in (host, dev):
            resident = c.probe(rows) >= 0
            if resident.any():
                c.touch(c.probe(rows)[resident], counts[resident])
            order = np.lexsort((rows[~resident], -counts[~resident]))
            c.admit(rows[~resident][order], payload[~resident][order],
                    counts[~resident][order])
        assert host.loc == dev.loc
        np.testing.assert_array_equal(host.slot_row, dev.slot_row)
        occupied = np.flatnonzero(host.slot_row >= 0)
        assert np.array_equal(host.read(occupied), dev.read(occupied))
    assert dev.evictions == host.evictions > 0
    assert dev.device_bytes() == 6 * DIM * 4


def test_device_warm_cache_scattered_slot_update():
    """Writes must land exactly whether the slots form one contiguous run
    (dynamic-update-slice path) or are fragmented (fused scatter path)."""
    c = DeviceWarmCache(8, 4, "lru")
    c._write_payload(np.array([7, 0, 3, 4]),             # fragmented
                     _payload(np.array([70, 0, 30, 40])))
    data = np.asarray(c.data)
    np.testing.assert_array_equal(data[0], np.full(4, 0.0))
    np.testing.assert_array_equal(data[3], np.full(4, 30.0))
    np.testing.assert_array_equal(data[4], np.full(4, 40.0))
    np.testing.assert_array_equal(data[7], np.full(4, 70.0))
    np.testing.assert_array_equal(data[[1, 2, 5, 6]], np.zeros((4, 4)))
    c._write_payload(np.array([2, 1]),                   # contiguous run
                     _payload(np.array([20, 10])))
    data = np.asarray(c.data)
    np.testing.assert_array_equal(data[1], np.full(4, 10.0))
    np.testing.assert_array_equal(data[2], np.full(4, 20.0))
    np.testing.assert_array_equal(data[7], np.full(4, 70.0))


def test_device_warm_ps_bit_exact_vs_dense_gather():
    tables = _tables()
    pats = _med_pats()
    ps = ParameterServer(tables,
                         PSConfig(hot_rows=16, warm_slots=32,
                                  warm_backing="device"),
                         trace=_batch(pats, 16, POOL, seed=0))
    assert all(isinstance(w, DeviceWarmCache) for w in ps.warm)
    for s in range(1, 6):
        idx = _batch(pats, 8, POOL, seed=s)
        assert np.array_equal(ps.lookup(idx), _dense(tables, idx))
    assert sum(w.insertions for w in ps.warm) > 0   # device path exercised


def test_ps_config_validates_new_knobs():
    with pytest.raises(ValueError, match="warm_backing"):
        PSConfig(warm_backing="l2")
    cfg = PSConfig(hot_rows=4, warm_slots=4, warm_backing="device",
                   async_prefetch=True)
    assert cfg.capacity_rows() == 8


# ---------------------------------------------------------------------------
# Planner-driven tier auto-tuning
# ---------------------------------------------------------------------------

def test_plan_tier_capacities_monotone_in_budget():
    trace = _batch(_med_pats(), 64, POOL, seed=0)
    prev_hot = prev_total = -1
    for budget in (0, 256, 1024, 4096, 16384, 65536, 262144, 2**22):
        p = plan_tier_capacities(trace, ROWS, DIM, budget)
        total = p.hot_rows + p.warm_slots
        assert p.hot_rows >= prev_hot
        assert total >= prev_total
        assert total <= p.budget_rows
        assert p.used_bytes <= max(budget, 0)
        assert 0.0 <= p.hot_coverage <= p.total_coverage <= 1.0
        prev_hot, prev_total = p.hot_rows, total
    assert prev_total == ROWS                    # huge budget: all resident


def test_plan_tier_capacities_shapes_and_edges():
    trace2d = _batch(_med_pats(), 32, POOL, seed=1)[:, 0]   # [N, L]
    p = plan_tier_capacities(trace2d, ROWS, DIM, 1 << 20)
    assert p.hot_rows + p.warm_slots == ROWS
    p0 = plan_tier_capacities(trace2d, ROWS, DIM, 0)
    assert p0.hot_rows == p0.warm_slots == 0
    assert any("cold" in n for n in p0.notes)
    # a trace with no recurring row => nothing worth pinning
    once = np.arange(ROWS, dtype=np.int64)[:, None, None]   # each row once
    p1 = plan_tier_capacities(once, ROWS, DIM, 1 << 30)
    assert p1.hot_rows == 0 and p1.warm_slots == ROWS


def test_ps_config_from_plan_and_ebc_autotune():
    pats = _med_pats()
    trace = _batch(pats, 32, POOL, seed=0)
    plan = plan_tier_capacities(trace, ROWS, DIM, 64 * 1024)
    cfg = PSConfig.from_plan(plan, async_prefetch=True, prefetch_depth=3)
    assert cfg.hot_rows == plan.hot_rows
    assert cfg.warm_slots == plan.warm_slots
    assert cfg.async_prefetch and cfg.prefetch_depth == 3

    ebc = EmbeddingBagCollection(EmbeddingStageConfig(
        num_tables=TABLES, rows=ROWS, dim=DIM, pooling=POOL,
        storage="tiered"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc.storage.build(params, trace=trace,
                      device_budget_bytes=64 * 1024,
                      async_prefetch=True)
    ps = ebc.storage.ps
    assert ps.cfg.hot_rows == plan.hot_rows
    assert ps.cfg.async_prefetch
    idx = _batch(pats, 8, POOL, seed=3)
    base = _dense(np.asarray(params["tables"]), idx)
    assert np.array_equal(ps.lookup(idx), base)
    ps.close()
    with pytest.raises(ValueError, match="device_budget_bytes"):
        ebc.storage.build(params)                # no cfg, no budget
    with pytest.raises(ValueError, match="overrides"):
        ebc.storage.build(params, PSConfig(hot_rows=1),
                          async_prefetch=True)


# ---------------------------------------------------------------------------
# Serving: async refresh driver + overlap stats
# ---------------------------------------------------------------------------

def test_serving_async_refresh_and_overlap_stats():
    emb = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                               pooling=POOL, storage="tiered")
    model = DLRM(DLRMConfig(embedding=emb, bottom_mlp=(64, DIM),
                            top_mlp=(32, 1)))
    params = model.init(jax.random.PRNGKey(0))
    stream = DLRMQueryStream(num_tables=TABLES, rows=ROWS, pooling=POOL,
                             batch_size=8, hotness="med_hot", seed=1)
    model.ebc.storage.build(
        params, PSConfig(hot_rows=32, warm_slots=32, window_batches=4,
                         async_prefetch=True),
        trace=stream.sample_trace(2))
    ps = model.ebc.storage.ps
    rest = jax.jit(lambda d, p: model.forward_from_pooled(params, d, p))

    def fwd(dense, idx):
        pooled = model.ebc.apply(params, idx)
        return rest(jnp.asarray(dense), pooled)

    srv = InferenceServer(fwd, BatcherConfig(max_batch=8, max_wait_s=0.0),
                          sla_ms=1e6, storage=model.ebc.storage,
                          refresh_every_batches=2, async_refresh=True)
    # submit two batches ahead so _stage_next() sees a full next batch
    for b in range(6):
        batch = stream.next_batch()
        for i in range(8):
            srv.submit(Query(qid=b * 8 + i, dense=batch.dense[i],
                             indices=batch.indices[i]))
        if b >= 1:
            srv.poll()
    srv.drain()
    srv.close()                                  # installs pending plan
    srv.close()                                  # idempotent
    ps.close()
    pct = srv.stats.percentiles()
    assert pct["served"] == 48
    # async refresh actually planned + installed off the serving path
    assert pct["refreshes"] >= 1
    assert pct.get("async_refreshes", 0) >= 1
    # overlap stats surfaced through ServeStats.percentiles()
    for key in ("queue_depth", "max_queue_depth", "off_critical_frac",
                "consume_overlap_frac", "consume_ready", "consume_waited"):
        assert key in pct, (key, pct)
    assert pct["max_queue_depth"] >= 1           # staging actually queued


def test_sync_refresh_driver_unchanged():
    """async_refresh=False keeps the PR-1 blocking refresh semantics."""
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16,
                                             window_batches=4))

    def fwd(dense, idx):
        ps.lookup(idx)
        return np.zeros(len(dense), np.float32)

    from repro.storage.tiered import TieredStorage
    srv = InferenceServer(fwd, BatcherConfig(max_batch=4, max_wait_s=0.0),
                          sla_ms=1e6, storage=TieredStorage.adopt(ps),
                          refresh_every_batches=1)
    idx = _batch(pats, 4, POOL, seed=0)
    for q in range(4):
        srv.submit(Query(qid=q, dense=np.zeros(2, np.float32),
                         indices=idx[q]))
    srv.drain(timeout_s=1.0)
    assert ps.refreshes == 1
    assert srv.stats.async_refreshes == 0
    srv.close()                                  # no-op without async pool
