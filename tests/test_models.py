"""Per-architecture smoke tests (required deliverable f): reduced config of
the same family, one forward + one train step on CPU, shapes + finiteness.
Plus decode-path consistency checks against teacher forcing.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_ARCHS, get_config, reduced
from repro.models import build_model, build_plan
from repro.models.config import shapes_for

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 32

    if cfg.is_encoder_decoder:
        frames = jax.random.normal(RNG, (B, cfg.encoder_seq_len, cfg.d_model))
        toks = jax.random.randint(RNG, (B, cfg.decoder_text_len), 0,
                                  cfg.vocab_size)
        enc = model.encode(params, frames)
        assert enc.shape == (B, cfg.encoder_seq_len, cfg.d_model)
        logits, _ = model.decode(params, toks, enc)
        assert logits.shape == (B, cfg.decoder_text_len, cfg.vocab_size)
        loss, grads = jax.value_and_grad(model.loss)(params, frames, toks,
                                                     toks)
    else:
        toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
        ve = (jax.random.normal(RNG, (B, cfg.vision_prefix_tokens,
                                      cfg.d_model))
              if cfg.vision_prefix_tokens else None)
        logits = model.forward(params, toks, vision_embeds=ve)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        loss, grads = jax.value_and_grad(model.loss)(params, toks, toks,
                                                     vision_embeds=ve)
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    assert any(g > 0 for g in gnorms), "gradients all zero"


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "deepseek-v2-lite-16b",
                                  "rwkv6-7b", "gemma3-27b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode_step logits == forward logits at each position."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full = model.forward(params, toks)

    cache = model.init_cache(B, S, dtype=jnp.float32)
    logits_p, cache = model.prefill(params, toks[:, :4], cache)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, 3]), rtol=2e-2, atol=2e-2)
    for t in range(4, S):
        step_logits, cache = model.decode_step(params, toks[:, t:t + 1],
                                               cache, t)
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full[:, t]), rtol=2e-2,
                                   atol=2e-2)


def test_whisper_decode_cached_matches_full():
    cfg = reduced(get_config("whisper-medium"))
    model = build_model(cfg)
    params = model.init(RNG)
    B = 1
    frames = jax.random.normal(RNG, (B, cfg.encoder_seq_len, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                              cfg.vocab_size)
    enc = model.encode(params, frames)
    full, _ = model.decode(params, toks, enc)

    cache = model.init_cache(B, 8, dtype=jnp.float32)
    for t in range(4):
        step, cache = model.decode(params, toks[:, t:t + 1], enc,
                                   cache=cache, cache_pos=t)
        np.testing.assert_allclose(np.asarray(step[:, 0]),
                                   np.asarray(full[:, t]), rtol=2e-2,
                                   atol=2e-2)


def test_stack_plans():
    jamba = get_config("jamba-1.5-large-398b")
    plan = build_plan(jamba)
    assert plan.num_layers == 72
    assert len(plan.pattern) == 8
    assert plan.pattern[0].mixer == "attn"
    assert all(s.mixer == "mamba" for s in plan.pattern[1:])
    assert sum(s.ffn == "moe" for s in plan.pattern) == 4

    gemma = get_config("gemma3-27b")
    plan = build_plan(gemma)
    assert plan.num_layers == 62
    assert len(plan.suffix) == 2           # 62 = 10*6 + 2
    assert plan.pattern[-1].mixer == "attn"
    assert all(s.mixer == "attn_local" for s in plan.pattern[:-1])

    ds = get_config("deepseek-v2-lite-16b")
    plan = build_plan(ds)
    assert plan.num_layers == 27
    assert len(plan.prefix) == 1 and plan.prefix[0].ffn == "dense"
    assert plan.pattern[0].ffn == "moe" and plan.pattern[0].mixer == "mla"


def test_shape_skips_documented():
    """long_500k only for sub-quadratic archs (DESIGN §Arch-applicability)."""
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        if cfg.family in ("hybrid", "ssm"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch


def test_full_param_counts_match_advertised():
    from repro.models import param_count
    expected = {
        "jamba-1.5-large-398b": (380e9, 420e9),
        "llama4-scout-17b-a16e": (100e9, 115e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "rwkv6-7b": (7e9, 8e9),
        "phi4-mini-3.8b": (3.5e9, 4.2e9),
        "minitron-8b": (7e9, 8.5e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),
        "gemma3-27b": (26e9, 30e9),
        "qwen2-vl-2b": (1.3e9, 2.2e9),
        "whisper-medium": (0.7e9, 1.0e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
