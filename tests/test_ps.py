"""Tiered embedding parameter server (repro/ps) + serving integration.

Covers the acceptance contract: tiered lookup is bit-exact vs the dense
`jnp.take` path, eviction respects capacity, refresh re-plans from a new
trace window, stats counters sum to total lookups, a med_hot trace reaches
>= 80% hot+warm hit rate at <= 20% tier capacity, and the Batcher drain
starvation fix.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern)
from repro.data import DLRMQueryStream
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import ParameterServer, PSConfig, PrefetchQueue, WarmCache
from repro.ps.prefetch import StagedBatch
from repro.serving import Batcher, BatcherConfig, InferenceServer, Query

ROWS, TABLES, DIM, POOL = 256, 4, 32, 6


def _tables(rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return rng.normal(size=(TABLES, ROWS, DIM)).astype(np.float32)


def _batch(pats, batch, pooling, seed):
    return np.stack([p.sample(batch, pooling, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _med_pats(rows=ROWS):
    return [make_pattern("med_hot", rows, seed=t) for t in range(TABLES)]


# ---------------------------------------------------------------------------
# bit-exactness
# ---------------------------------------------------------------------------

def test_tiered_bit_exact_vs_device():
    cfg0 = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla")
    ebc0 = EmbeddingBagCollection(cfg0)
    params = ebc0.init(jax.random.PRNGKey(0))
    pats = _med_pats()
    idx = _batch(pats, 8, POOL, seed=0)
    base = np.asarray(ebc0.apply(params, jnp.asarray(idx)))

    cfgt = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, storage="tiered")
    ebct = EmbeddingBagCollection(cfgt)
    ebct.storage.build(params, PSConfig(hot_rows=32, warm_slots=32),
                       trace=idx)
    out = np.asarray(ebct.apply(params, jnp.asarray(idx)))
    assert np.array_equal(out, base)  # bit-identical, not just close

    # stays exact across further batches (warm churn + prefetch + refresh)
    for seed in range(1, 6):
        idx = _batch(pats, 8, POOL, seed=seed)
        if seed == 2:
            ebct.storage.ps.stage(_batch(pats, 8, POOL, seed=3))
        if seed == 4:
            ebct.storage.ps.refresh()
        out = np.asarray(ebct.apply(params, jnp.asarray(idx)))
        base = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
        assert np.array_equal(out, base)


def test_tiered_bit_exact_weighted_mean():
    cfg0 = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla", combine="mean")
    ebc0 = EmbeddingBagCollection(cfg0)
    params = ebc0.init(jax.random.PRNGKey(1))
    idx = _batch(_med_pats(), 8, POOL, seed=0)
    w = np.random.default_rng(3).random((8, TABLES, POOL)).astype(np.float32)
    base = np.asarray(ebc0.apply(params, jnp.asarray(idx), jnp.asarray(w)))

    cfgt = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, storage="tiered",
                                combine="mean")
    ebct = EmbeddingBagCollection(cfgt)
    ebct.storage.build(params, PSConfig(hot_rows=16, warm_slots=16))
    out = np.asarray(ebct.apply(params, jnp.asarray(idx), jnp.asarray(w)))
    assert np.array_equal(out, base)


def test_tiered_requires_ps_and_rejects_double_remap():
    cfgt = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, storage="tiered")
    ebc = EmbeddingBagCollection(cfgt)
    with pytest.raises(RuntimeError, match="ParameterServer"):
        ebc.apply({"tables": None}, jnp.zeros((2, TABLES, POOL), jnp.int32))
    with pytest.raises(ValueError, match="pinned_rows"):
        EmbeddingBagCollection(EmbeddingStageConfig(
            num_tables=TABLES, rows=ROWS, dim=DIM, pooling=POOL,
            storage="tiered", pinned_rows=8))
    with pytest.raises(ValueError, match="storage"):
        EmbeddingBagCollection(EmbeddingStageConfig(storage="floppy"))


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------

def test_eviction_respects_capacity():
    pats = _med_pats()
    for policy in ("lfu", "lru"):
        ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=24,
                                                 eviction=policy))
        for seed in range(8):
            ps.lookup(_batch(pats, 16, POOL, seed=seed))
        st = ps.stats()
        assert st["evictions"] > 0          # churn actually happened
        for w in ps.warm:
            assert len(w) <= w.capacity
            assert (w.slot_row >= 0).sum() == len(w.loc)
            # tag store consistent: every loc entry points at its row
            for r, s in w.loc.items():
                assert w.slot_row[s] == r


def test_warm_cache_lfu_evicts_least_frequent():
    c = WarmCache(2, 4, "lfu")
    c.admit(np.array([10, 20]), np.ones((2, 4), np.float32),
            np.array([5, 1]))
    # row 20 (freq 1) is the victim when 30 arrives
    c.admit(np.array([30]), np.zeros((1, 4), np.float32), np.array([2]))
    assert set(c.loc) == {10, 30}
    assert c.evictions == 1


def test_warm_cache_lru_evicts_least_recent():
    c = WarmCache(2, 4, "lru")
    c.admit(np.array([1]), np.ones((1, 4), np.float32), np.array([9]))
    c.admit(np.array([2]), np.ones((1, 4), np.float32), np.array([1]))
    c.touch(c.probe(np.array([1])), np.array([1]))   # row 1 now most recent
    c.admit(np.array([3]), np.ones((1, 4), np.float32), np.array([1]))
    assert set(c.loc) == {1, 3}                      # row 2 evicted


def test_stats_counters_sum_to_total():
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=32, warm_slots=32))
    for seed in range(6):
        ps.lookup(_batch(pats, 16, POOL, seed=seed))
    st = ps.stats()
    assert st["total_accesses"] == 6 * 16 * TABLES * POOL
    assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
            == st["total_accesses"])
    assert 0.0 <= st["cache_hit_rate"] <= 1.0


def test_refresh_replans_from_new_trace_window():
    pats = _med_pats()
    # identity plans: hot tier pins rows [0, K) — wrong for scattered traffic
    ps = ParameterServer(_tables(), PSConfig(hot_rows=48, warm_slots=0,
                                             window_batches=4))
    old_hot = ps.plans[0].perm[:48].copy()
    for seed in range(4):
        ps.lookup(_batch(pats, 32, POOL, seed=seed))
    cold_rate = ps.stats()["hot_hit_rate"]
    assert ps.refresh()["replanned"]
    assert not np.array_equal(ps.plans[0].perm[:48], old_hot)
    ps.reset_stats()
    for seed in range(4, 8):
        ps.lookup(_batch(pats, 32, POOL, seed=seed))
    hot_rate = ps.stats()["hot_hit_rate"]
    assert hot_rate > cold_rate + 0.2   # re-pinning recovered the hot set
    assert ps.refreshes == 1


def test_prefetch_queue_stage_consume():
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16,
                                             prefetch_depth=1))
    nxt = _batch(pats, 8, POOL, seed=1)
    assert ps.stage(nxt)
    assert not ps.stage(_batch(pats, 8, POOL, seed=2))   # queue full
    ps.lookup(nxt)
    st = ps.stats()
    assert st["prefetch_hits"] > 0
    assert st["queue_depth"] == 0
    # staged rows were gathered at stage time, not at lookup time
    assert st["staged_rows"] >= st["prefetch_hits"]


def test_prefetch_split_misses_partitions_exactly():
    q = PrefetchQueue(depth=2)
    staged = StagedBatch(
        indices=np.zeros((1, 1, 1), np.int32),
        rows={0: np.array([2, 5, 9])},
        data={0: np.arange(12, dtype=np.float32).reshape(3, 4)})
    hit_rows, hit_data, residual = q.split_misses(staged, 0,
                                                  np.array([2, 7, 9]))
    np.testing.assert_array_equal(hit_rows, [2, 9])
    np.testing.assert_array_equal(hit_data,
                                  staged.data[0][[0, 2]])
    np.testing.assert_array_equal(residual, [7])
    assert q.prefetch_hits == 2 and q.prefetch_misses == 1


def test_ps_config_validation():
    with pytest.raises(ValueError, match="eviction"):
        PSConfig(eviction="fifo")
    with pytest.raises(ValueError, match="capacities"):
        PSConfig(hot_rows=-1)
    assert PSConfig(hot_rows=10, warm_slots=6).capacity_rows() == 16


# ---------------------------------------------------------------------------
# acceptance benchmark: med_hot, capacity <= 20% of rows, hit rate >= 80%
# ---------------------------------------------------------------------------

def test_hit_rate_med_hot_at_20pct_capacity():
    rows, batch, pooling = 2000, 256, 20
    pats = [make_pattern("med_hot", rows, seed=t) for t in range(TABLES)]
    tables = np.zeros((TABLES, rows, 8), np.float32)
    cfg = PSConfig(hot_rows=200, warm_slots=200)      # 400/2000 = 20%
    trace = np.concatenate(
        [_batch(pats, batch, pooling, seed=s) for s in range(3)], axis=0)
    ps = ParameterServer(tables, cfg, trace=trace)
    for seed in range(3, 6):                          # warm the cache
        ps.lookup(_batch(pats, batch, pooling, seed=seed))
    ps.reset_stats()
    for seed in range(6, 12):                         # measured window
        ps.lookup(_batch(pats, batch, pooling, seed=seed))
    st = ps.stats()
    assert st["cache_hit_rate"] >= 0.80, st
    assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
            == st["total_accesses"])


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_batcher_drain_force_flushes_partial_batch():
    """Regression: a sub-max_batch remainder with a long batching window
    must not starve/busy-spin in drain()."""
    served = []

    def fwd(dense, idx):
        served.append(len(dense))
        return np.zeros(len(dense), np.float32)

    srv = InferenceServer(fwd, BatcherConfig(max_batch=8, max_wait_s=60.0),
                          sla_ms=1e6)
    for q in range(3):
        srv.submit(Query(qid=q, dense=np.zeros(4, np.float32),
                         indices=np.zeros((TABLES, POOL), np.int32)))
    t0 = time.perf_counter()
    srv.drain(timeout_s=0.2)
    assert srv.stats.served == 3
    assert time.perf_counter() - t0 < 5.0     # no 60s window wait
    assert not srv.batcher.queue


def test_padded_partial_batch_not_counted_as_traffic():
    """Batcher zero-padding must not inflate PS stats or the refresh
    window (the padded rows still get served values — shape stability)."""
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16))

    def fwd(dense, idx):
        rows = ps.lookup(idx)
        assert rows.shape == (8, TABLES, POOL, DIM)   # padded shape served
        return np.zeros(len(dense), np.float32)

    from repro.storage.tiered import TieredStorage
    srv = InferenceServer(fwd, BatcherConfig(max_batch=8, max_wait_s=0.0),
                          sla_ms=1e6, storage=TieredStorage.adopt(ps))
    idx = _batch(pats, 3, POOL, seed=0)
    for q in range(3):
        srv.submit(Query(qid=q, dense=np.zeros(4, np.float32),
                         indices=idx[q]))
    srv.drain(timeout_s=1.0)
    assert srv.stats.served == 3
    st = ps.stats()
    assert st["total_accesses"] == 3 * TABLES * POOL   # not 8 * T * L
    assert ps.window[-1].shape[0] == 3                 # window holds real n
    assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
            == st["total_accesses"])


def test_flush_drops_warm_and_window_but_keeps_stats():
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16))
    ps.lookup(_batch(pats, 8, POOL, seed=0))
    assert sum(len(w) for w in ps.warm) > 0 and len(ps.window) == 1
    total = ps.stats()["total_accesses"]
    ps.flush()
    assert sum(len(w) for w in ps.warm) == 0
    assert len(ps.window) == 0
    assert ps.stats()["total_accesses"] == total       # counters untouched


def test_stage_skips_gathers_when_queue_full():
    pats = _med_pats()
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16,
                                             prefetch_depth=1))
    assert ps.stage(_batch(pats, 8, POOL, seed=1))
    gathered = ps.cold.gathered_rows
    assert not ps.stage(_batch(pats, 8, POOL, seed=2))
    assert ps.cold.gathered_rows == gathered   # no wasted cold gathers


def test_batcher_next_batch_force():
    b = Batcher(BatcherConfig(max_batch=4, max_wait_s=60.0))
    b.submit(Query(qid=0, dense=np.zeros(1), indices=np.zeros((1, 1))))
    assert b.next_batch() is None             # window open, batch partial
    out = b.next_batch(force=True)
    assert out is not None and len(out) == 1


def test_serving_tiered_end_to_end_stats_and_refresh():
    emb = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                               pooling=POOL, storage="tiered")
    model = DLRM(DLRMConfig(embedding=emb, bottom_mlp=(64, DIM),
                            top_mlp=(32, 1)))
    params = model.init(jax.random.PRNGKey(0))
    stream = DLRMQueryStream(num_tables=TABLES, rows=ROWS, pooling=POOL,
                             batch_size=8, hotness="med_hot", seed=1)
    model.ebc.storage.build(
        params, PSConfig(hot_rows=32, warm_slots=32, window_batches=4),
        trace=stream.sample_trace(2))
    ps = model.ebc.storage.ps
    rest = jax.jit(lambda d, p: model.forward_from_pooled(params, d, p))

    def fwd(dense, idx):
        pooled = model.ebc.apply(params, idx)     # host PS + jitted pool
        return rest(jnp.asarray(dense), pooled)

    srv = InferenceServer(fwd, BatcherConfig(max_batch=8, max_wait_s=0.0),
                          sla_ms=1e6, storage=model.ebc.storage,
                          refresh_every_batches=2)
    for _ in range(4):
        b = stream.next_batch()
        for i in range(8):
            srv.submit(Query(qid=i, dense=b.dense[i], indices=b.indices[i]))
        srv.poll()
    srv.drain()
    pct = srv.stats.percentiles()
    assert pct["served"] == 32
    # cache statistics surfaced through ServeStats.percentiles()
    for key in ("hot_hit_rate", "warm_hit_rate", "cache_hit_rate",
                "cold_misses", "evictions", "refreshes"):
        assert key in pct, pct
    assert pct["refreshes"] >= 1              # periodic re-pinning ran
    # dense-path reference: identical scores for the same queries
    emb0 = EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla")
    model0 = DLRM(DLRMConfig(embedding=emb0, bottom_mlp=(64, DIM),
                             top_mlp=(32, 1)))
    stream0 = DLRMQueryStream(num_tables=TABLES, rows=ROWS, pooling=POOL,
                              batch_size=8, hotness="med_hot", seed=1)
    b0 = stream0.next_batch()
    want = model0.forward(params, jnp.asarray(b0.dense),
                          jnp.asarray(b0.indices))
    got = fwd(b0.dense, b0.indices)
    # scores agree to float32 noise (MLP halves run under different jit
    # fusions; the embedding stage itself is bit-exact — see tests above)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
