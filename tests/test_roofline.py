"""Roofline analyzer calibration.

The critical property: scanned (while-loop) programs must report the same
totals as their unrolled equivalents — XLA's own cost_analysis reports while
bodies once, which is exactly what this parser corrects.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analyze import (HloCost, roofline_terms,
                                    xla_cost_analysis)
from repro.roofline.hw import PEAK_FLOPS_BF16


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_flops_simple_matmul():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    got = HloCost(c.as_text()).total()["flops"]
    want = 2 * 128 * 256 * 64
    assert abs(got - want) / want < 0.05


def test_flops_match_xla_on_flat_module():
    """No control flow => our parser should agree with cost_analysis."""
    def fn(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2
    x = jnp.zeros((64, 128))
    w1 = jnp.zeros((128, 256))
    w2 = jnp.zeros((256, 32))
    c = _compile(fn, x, w1, w2)
    mine = HloCost(c.as_text()).total()["flops"]
    xla = xla_cost_analysis(c)["flops"]
    assert abs(mine - xla) / xla < 0.10


def test_scan_flops_scale_with_trip_count():
    w = jnp.zeros((16, 64, 64))

    def scanned(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    def unrolled(x, w):
        h = x
        for i in range(16):
            h = jnp.tanh(h @ w[i])
        return h

    x = jnp.zeros((8, 64))
    fl_scan = HloCost(_compile(scanned, x, w).as_text()).total()["flops"]
    fl_unroll = HloCost(_compile(unrolled, x, w).as_text()).total()["flops"]
    assert fl_unroll > 0
    assert abs(fl_scan - fl_unroll) / fl_unroll < 0.05, \
        (fl_scan, fl_unroll)
    # and XLA's own number misses the trip count (documents why we parse)
    xla = xla_cost_analysis(_compile(scanned, x, w))["flops"]
    assert xla < 0.5 * fl_unroll


def test_nested_scan_trip_counts():
    w = jnp.zeros((4, 64, 64))

    def nested(x, w):
        def outer(h, wi):
            def inner(g, _):
                return jnp.tanh(g @ wi), None
            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    x = jnp.zeros((8, 64))
    fl = HloCost(_compile(nested, x, w).as_text()).total()["flops"]
    want = 4 * 3 * 2 * 8 * 64 * 64
    assert abs(fl - want) / want < 0.10


def test_bytes_reasonable_for_copy_free_reduction():
    x = jnp.zeros((1024, 1024), jnp.float32)  # 4 MiB
    c = _compile(lambda v: v.sum(), x)
    by = HloCost(c.as_text()).total()["bytes"]
    assert 4e6 * 0.5 < by < 4e6 * 4  # ~one read of the input


def test_dus_charged_as_update_region():
    buf = jnp.zeros((1024, 1024), jnp.float32)
    upd = jnp.ones((1, 1024), jnp.float32)

    def fn(b, u, i):
        return jax.lax.dynamic_update_slice(b, u, (i, 0))
    c = _compile(fn, buf, upd, jnp.int32(5))
    by = HloCost(c.as_text()).total()["bytes"]
    assert by < 1024 * 1024 * 4 * 0.5, by  # NOT the whole buffer


def test_collectives_counted(multidevice):
    out = multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.roofline.analyze import HloCost
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("d",))
x = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
sh = NamedSharding(mesh, P("d", None))
c = jax.jit(lambda v: v.sum(), in_shardings=(sh,),
            out_shardings=NamedSharding(mesh, P())).lower(x).compile()
t = HloCost(c.as_text()).total()
print("COLL", t["collective_bytes"])
assert t["collective_bytes"] > 0, t
""", ndev=8)
    assert "COLL" in out


def test_roofline_terms_shape():
    a = jnp.zeros((256, 256))
    c = _compile(lambda x: x @ x, a)
    t = roofline_terms(c.as_text(), num_chips=4)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t["compute_s"] == pytest.approx(
        t["per_device_flops"] / PEAK_FLOPS_BF16)
