"""Minimal in-repo fallback for the `hypothesis` API this suite uses.

The container has no `hypothesis` wheel and the repo forbids ad-hoc
installs, so tests/conftest.py puts this package on sys.path ONLY when the
real library is absent (`pip install -e .[dev]` environments get the real
thing — see pyproject.toml). It implements just `given`, `settings`, and
`strategies.integers`, running each property `max_examples` times with a
fixed-seed PRNG: deterministic, no shrinking, no database — enough to keep
the property tests meaningful as randomized-example tests.
"""
from __future__ import annotations

import functools
import random
import types

__version__ = "0.0-repro-stub"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


def _integers(min_value=0, max_value=2**31 - 1) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(int(min_value), int(max_value)))


def _floats(min_value=0.0, max_value=1.0, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(float(min_value),
                                             float(max_value)))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, booleans=_booleans,
    sampled_from=_sampled_from)

_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    for name, s in strats.items():
        if not isinstance(s, _Strategy):
            raise TypeError(f"@given({name}=...) expects a strategy")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # hide the wrapped signature: pytest must not treat the drawn
        # property arguments as fixtures
        del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco
