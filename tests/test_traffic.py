"""Traffic subsystem: generators, virtual clock, and trace replay.

Covers the determinism contract (`--seed` reproducibility: same args ->
byte-identical streams), each rate profile's shape (steady spacing,
diurnal swing, flash-crowd density), the hotness-shift axis (pre-shift
stream unperturbed, post-shift hot set moves), the virtual clock's
monotonicity, replay on a real `ServingSession` (everything served at low
offered load, timeline coherent with trace time), and the
`plan_admission` sizing helper.
"""
import numpy as np
import jax
import pytest

from repro.core import EmbeddingStageConfig, plan_admission
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import PSConfig
from repro.serving import BatcherConfig, ServingSession, SLOConfig
from repro.traffic import (TRACE_KINDS, DiurnalRate, FlashCrowdRate,
                           SteadyRate, TrafficGenerator, VirtualClock,
                           make_traffic, replay)

ROWS, TABLES, POOL = 512, 4, 6


def _gen(kind="steady", **kw):
    kw.setdefault("base_qps", 100.0)
    kw.setdefault("num_tables", TABLES)
    kw.setdefault("rows", ROWS)
    kw.setdefault("pooling", POOL)
    return make_traffic(kind, **kw)


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock_advances_and_rejects_backwards():
    clk = VirtualClock()
    assert clk() == 0.0
    assert clk.advance(1.5) == 1.5
    clk.advance(0.0)                    # zero advance is legal (no-op)
    assert clk() == clk.now == 1.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    assert clk.now == 1.5               # failed advance left time untouched


# ---------------------------------------------------------------------------
# rate profiles
# ---------------------------------------------------------------------------

def test_steady_arrivals_evenly_spaced():
    g = _gen("steady", base_qps=50.0)
    t = g.arrival_times(100)
    assert t[0] == 0.0
    np.testing.assert_allclose(np.diff(t), 1.0 / 50.0)


def test_diurnal_rate_swings_and_validates():
    prof = DiurnalRate(base_qps=100.0, amplitude=0.5, period_s=10.0)
    ts = np.linspace(0.0, 10.0, 500)
    rates = np.array([prof.rate(t) for t in ts])
    assert rates.max() > 140.0 and rates.min() < 60.0    # ~base*(1 +/- 0.5)
    assert rates.min() > 0.0                             # never stalls
    with pytest.raises(ValueError):
        DiurnalRate(base_qps=100.0, amplitude=1.0)       # rate could hit 0
    # arrivals strictly increase even at the trough
    t = _gen("diurnal", base_qps=100.0, period_s=10.0).arrival_times(2000)
    assert np.all(np.diff(t) > 0)


def test_flash_crowd_densifies_the_spike_window():
    g = _gen("flash", base_qps=100.0, spike_qps=1000.0,
             spike_start_s=1.0, spike_len_s=1.0)
    t = g.arrival_times(1300)
    in_spike = np.count_nonzero((t >= 1.0) & (t < 2.0))
    # ~1000 arrivals land inside the 1s spike vs ~100 per steady second
    assert in_spike > 800
    before = np.count_nonzero(t < 1.0)
    assert 80 <= before <= 120
    assert FlashCrowdRate(100.0, 1000.0, 1.0, 1.0).in_spike(1.5)
    assert not FlashCrowdRate(100.0, 1000.0, 1.0, 1.0).in_spike(2.5)


# ---------------------------------------------------------------------------
# determinism (the --seed contract)
# ---------------------------------------------------------------------------

def test_same_args_byte_identical_stream():
    for kind in TRACE_KINDS:
        a = _gen(kind, seed=7).queries(64)
        b = _gen(kind, seed=7).queries(64)
        assert [q.arrival_s for q in a] == [q.arrival_s for q in b]
        for qa, qb in zip(a, b):
            assert qa.qid == qb.qid
            np.testing.assert_array_equal(qa.dense, qb.dense)
            np.testing.assert_array_equal(qa.indices, qb.indices)


def test_seed_changes_the_stream():
    a = _gen("steady", seed=0).queries(64)
    b = _gen("steady", seed=1).queries(64)
    assert not all(np.array_equal(qa.indices, qb.indices)
                   for qa, qb in zip(a, b))
    assert not np.array_equal(a[0].dense, b[0].dense)


def test_tables_get_distinct_patterns():
    q = _gen("steady", seed=0).queries(64)
    idx = np.stack([x.indices for x in q])          # [N, T, L]
    flat = [idx[:, t].reshape(-1) for t in range(TABLES)]
    assert not all(np.array_equal(flat[0], f) for f in flat[1:])


# ---------------------------------------------------------------------------
# hotness shift
# ---------------------------------------------------------------------------

def test_shift_preserves_pre_stream_and_moves_the_hot_set():
    base = _gen("steady", base_qps=100.0, seed=3).queries(400)
    shifted = _gen("shift", base_qps=100.0, seed=3,
                   shift_at_s=2.0).queries(400)
    pre = [i for i, q in enumerate(shifted) if q.arrival_s < 2.0]
    post = [i for i, q in enumerate(shifted) if q.arrival_s >= 2.0]
    assert pre and post
    for i in pre:                      # adding a shift never rewrites the
        np.testing.assert_array_equal(  # already-emitted prefix
            shifted[i].indices, base[i].indices)
    # post-shift the hot SET moves: top rows before/after barely overlap
    def top_rows(ids):
        counts = np.bincount(np.concatenate(ids).reshape(-1),
                             minlength=ROWS)
        return set(np.argsort(-counts)[:10].tolist())
    hot_pre = top_rows([shifted[i].indices[:, 0] for i in pre])
    hot_post = top_rows([shifted[i].indices[:, 0] for i in post])
    assert len(hot_pre & hot_post) < 5


def test_make_traffic_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown trace kind"):
        _gen("tsunami")


# ---------------------------------------------------------------------------
# replay on a real session
# ---------------------------------------------------------------------------

def _session(slo=None, clock=None):
    cfg = DLRMConfig(embedding=EmbeddingStageConfig(
        num_tables=TABLES, rows=ROWS, dim=16, pooling=POOL,
        storage="tiered"),
        bottom_mlp=(32, 16), top_mlp=(16, 1))
    model = DLRM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = np.stack([q.indices for q in _gen("steady").queries(32)])
    model.ebc.storage.build(
        params, PSConfig(hot_rows=64, warm_slots=64), trace=trace)
    return ServingSession(
        model, params,
        batcher=BatcherConfig(max_batch=8, max_wait_s=0.05),
        slo=slo, clock=clock)


def test_replay_requires_a_virtual_clock():
    sess = _session()                   # real perf_counter clock
    try:
        with pytest.raises(TypeError, match="VirtualClock"):
            replay(sess, _gen("steady").queries(4))
    finally:
        sess.close()


def test_replay_steady_low_load_serves_everything():
    sess = _session(clock=VirtualClock())
    try:
        queries = _gen("steady", base_qps=100.0, seed=1).queries(64)
        rep = replay(sess, queries)
        assert rep.submitted == 64
        assert rep.shed == 0 and rep.shed_frac == 0.0
        assert rep.admitted == rep.served == 64
        assert rep.percentiles["served"] == 64
        assert rep.percentiles["shed_queries"] == 0
        # timeline is coherent with trace time: monotone stamps, served
        # counts non-decreasing, final snapshot saw every query
        t = [s.t_s for s in rep.timeline]
        assert t == sorted(t)
        served = [s.served for s in rep.timeline]
        assert served == sorted(served) and served[-1] == 64
        assert all(not s.degraded and s.slo_level == 0
                   for s in rep.timeline)
        assert rep.final_windowed_p99_ms() > 0.0
        # at 100qps a batch of 8 fills in 80ms >> the 50ms window: every
        # batch is a partial flushed at its deadline, so query latencies
        # never exceed window + one real service time (generous margin —
        # service is real host seconds)
        assert all(lat <= 0.05 + 0.25
                   for lat in sess.stats.query_latencies_s)
    finally:
        sess.close()


def test_replay_snapshots_after_filters_by_time():
    sess = _session(clock=VirtualClock())
    try:
        rep = replay(sess, _gen("steady", base_qps=100.0).queries(32))
        mid = rep.timeline[len(rep.timeline) // 2].t_s
        late = rep.snapshots_after(mid)
        assert late and all(s.t_s >= mid for s in late)
        assert len(late) < len(rep.timeline)
    finally:
        sess.close()


# ---------------------------------------------------------------------------
# admission planning (core.plan)
# ---------------------------------------------------------------------------

def test_plan_admission_sizes_queue_from_budget():
    plan = plan_admission(target_p99_ms=10.0, batch_service_ms=2.0,
                          max_batch=32, headroom=0.8)
    assert plan.deadline_ms == pytest.approx(8.0)
    assert plan.batches_in_budget == 4
    assert plan.max_queue == 4 * 32
    assert plan.sustainable_qps == pytest.approx(16000.0)
    assert plan.notes == ()


def test_plan_admission_floors_at_one_batch():
    plan = plan_admission(target_p99_ms=1.0, batch_service_ms=5.0,
                          max_batch=16)
    assert plan.batches_in_budget == 1 and plan.max_queue == 16
    assert plan.notes                   # warns the budget is unservable


def test_plan_admission_monotone_in_target():
    queues = [plan_admission(t, 2.0, 32).max_queue
              for t in (4.0, 8.0, 16.0, 64.0)]
    assert queues == sorted(queues)


def test_plan_admission_validates():
    with pytest.raises(ValueError):
        plan_admission(0.0, 2.0, 32)
    with pytest.raises(ValueError):
        plan_admission(10.0, -1.0, 32)
    with pytest.raises(ValueError):
        plan_admission(10.0, 2.0, 0)
    with pytest.raises(ValueError):
        plan_admission(10.0, 2.0, 32, headroom=1.5)
