"""Frequency-aware shard placement + runtime auto-tuners (PR 4).

Pins the acceptance contract: on a skewed synthetic trace the LPT-balanced
placement beats the contiguous split's imbalance ratio; sharded lookups
stay bit-exact under arbitrary AND replicated placements; the queue-depth
controller converges and can never leave its bound; the `device` backend
ignores every tuning hook; and the `tools/check_bench.py` CI gate
hard-fails on schema drift while only warning on timing drift.
"""
import importlib.util
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern)
from repro.core import plan_shard_placement as core_plan_shard_placement
from repro.core.plan import estimate_device_budget
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import (AutoTuneConfig, ParameterServer, PSConfig,
                      QueueDepthController)
from repro.serving import BatcherConfig, ServingSession
from repro.storage import (ShardPlacement, estimate_table_loads,
                           plan_shard_placement)

ROWS, TABLES, DIM, POOL = 256, 6, 16, 6
# heavy tables stacked at one end => contiguous split is maximally lopsided
SKEWED = ("one_item", "one_item", "high_hot", "med_hot", "random", "random")


def _pats(hotness=SKEWED):
    return [make_pattern(h, ROWS, seed=t) for t, h in enumerate(hotness)]


def _batch(pats, batch, seed):
    return np.stack([p.sample(batch, POOL, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _trace(pats, batches=3, batch=8, seed0=50):
    return np.concatenate([_batch(pats, batch, seed0 + s)
                           for s in range(batches)], axis=0)


def _stage_cfg(storage="device", tables=TABLES):
    return EmbeddingStageConfig(num_tables=tables, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla",
                                storage=storage)


@pytest.fixture(scope="module")
def dense_ref():
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    params = ebc.init(jax.random.PRNGKey(0))
    return ebc, params


# ---------------------------------------------------------------------------
# load estimation + the planner
# ---------------------------------------------------------------------------

def test_estimate_table_loads_counts_batch_distinct_rows():
    # table 0: same row everywhere -> 1 distinct/batch; table 1: all rows
    # distinct -> L distinct/batch
    trace = np.stack([np.zeros((4, POOL), np.int64),
                      np.arange(4 * POOL).reshape(4, POOL)], axis=1)
    loads = estimate_table_loads(trace, row_bytes=8)
    assert loads[0] == pytest.approx(1 * 8)
    assert loads[1] == pytest.approx(POOL * 8)


def test_balanced_beats_contiguous_on_skewed_trace():
    """The acceptance assertion: LPT reduces max/mean shard load."""
    pats = _pats()
    trace = _trace(pats)
    loads = estimate_table_loads(trace, row_bytes=DIM * 4)
    cont = ShardPlacement.contiguous(TABLES, 2, loads=loads)
    bal = plan_shard_placement(trace, 2, row_bytes=DIM * 4)
    assert bal.imbalance_ratio() < cont.imbalance_ratio()
    assert cont.imbalance_ratio() > 1.1      # the mix really is skewed
    assert bal.imbalance_ratio() < 1.1       # and LPT really fixes it
    # every table assigned exactly once, to a real shard
    assert sorted(t for ts in bal.shard_tables for t in ts) \
        == list(range(TABLES))


def test_plan_shard_placement_deterministic_and_clamped():
    pats = _pats()
    trace = _trace(pats)
    a = plan_shard_placement(trace, 3)
    b = plan_shard_placement(trace, 3)
    assert a == b                             # fully deterministic
    # shard count clamps to the table count
    assert plan_shard_placement(trace, 64).num_shards == TABLES
    with pytest.raises(ValueError, match="num_shards"):
        plan_shard_placement(trace, 0)


def test_replication_splits_dominant_table_across_distinct_shards():
    loads = np.array([100.0, 5.0, 5.0, 5.0])
    trace = _trace(_pats(("random",) * 4), batch=4)[:, :4]
    plc = plan_shard_placement(trace, 3, loads=loads, replicate_factor=1.0)
    assert plc.replicated_tables == (0,)
    owners = plc.replicas[0]
    assert len(owners) == len(set(owners)) >= 2   # distinct shards
    # replication restores near-perfect balance despite the 20x outlier
    assert plc.imbalance_ratio() < 1.1
    # without the escape hatch the dominant table pins the imbalance
    no_rep = plan_shard_placement(trace, 3, loads=loads)
    assert plc.imbalance_ratio() < no_rep.imbalance_ratio()


def test_shard_placement_validation():
    with pytest.raises(ValueError, match="no shard"):
        ShardPlacement(num_tables=2, num_shards=2,
                       replicas=((0,), ()), loads=(1.0, 1.0))
    with pytest.raises(ValueError, match="twice"):
        ShardPlacement(num_tables=1, num_shards=2,
                       replicas=((0, 0),), loads=(1.0,))
    with pytest.raises(ValueError, match="unknown shard"):
        ShardPlacement(num_tables=1, num_shards=2,
                       replicas=((5,),), loads=(1.0,))
    with pytest.raises(ValueError, match="one entry per table"):
        ShardPlacement(num_tables=2, num_shards=1,
                       replicas=((0,),), loads=(1.0,))


def test_core_plan_exposes_planner_entry():
    """`plan_shard_placement` is reachable from the planner API surface."""
    trace = _trace(_pats())
    plc = core_plan_shard_placement(trace, 2, row_bytes=DIM * 4)
    assert isinstance(plc, ShardPlacement)
    assert plc.num_shards == 2


# ---------------------------------------------------------------------------
# bit-exactness under arbitrary / replicated placements
# ---------------------------------------------------------------------------

def _scrambled_placement(loads):
    """An adversarial non-contiguous hand placement."""
    return ShardPlacement(num_tables=TABLES, num_shards=3,
                          replicas=((2,), (0,), (2,), (1,), (0,), (1,)),
                          loads=tuple(float(x) for x in loads),
                          strategy="scrambled")


def _replicated_placement(loads):
    """Tables 4 and 5 (the heavy `random` ones) replicated across shards."""
    return ShardPlacement(num_tables=TABLES, num_shards=3,
                          replicas=((0,), (1,), (2,), (0,),
                                    (0, 1, 2), (1, 2)),
                          loads=tuple(float(x) for x in loads),
                          strategy="replicated")


@pytest.mark.parametrize("mk_placement,batch", [
    ("balanced", 8),
    (_scrambled_placement, 8),
    (_replicated_placement, 8),
    (_replicated_placement, 7),    # odd batch: uneven replica chunks
])
def test_sharded_bit_exact_under_placements(dense_ref, mk_placement, batch):
    ebc0, params = dense_ref
    pats = _pats()
    trace = _trace(pats)
    if callable(mk_placement):
        placement = mk_placement(estimate_table_loads(trace, DIM * 4))
    else:
        placement = mk_placement
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params,
                      PSConfig(hot_rows=32, warm_slots=32,
                               async_prefetch=True, window_batches=4),
                      trace=trace, num_shards=3, placement=placement)
    with ebc.storage:
        for seed in range(5):
            idx = _batch(pats, batch, seed=seed)
            if seed == 1:       # staged payloads must not change values
                ebc.storage.stage(_batch(pats, batch, seed=2))
            if seed == 3:       # neither must a mid-stream re-pin
                ebc.storage.refresh()
            got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
            want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
            assert np.array_equal(got, want), seed
        st = ebc.storage.stats()
        assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
                == st["total_accesses"])
        assert len(st["per_shard"]) == 3     # one entry per SHARD


def test_replicated_placement_partial_batch_bit_exact(dense_ref):
    """Regression: a partial (force-flushed) batch whose valid rows end
    BEFORE a replica's batch slice must serve bit-exactly — the all-padding
    chunk takes the direct cold path instead of a zero-size recursion."""
    ebc0, params = dense_ref
    pats = _pats()
    trace = _trace(pats)
    plc = _replicated_placement(estimate_table_loads(trace, DIM * 4))
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8),
                      trace=trace, placement=plc)
    with ebc.storage:
        idx = _batch(pats, 9, seed=0)
        # table 4 has 3 replicas -> chunks [0,3), [3,6), [6,9); valid=2
        # leaves the 2nd and 3rd replica chunks entirely padding
        ebc.storage.hint_valid(2)
        got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
        want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
        assert np.array_equal(got, want)
        assert ebc.storage.stats()["total_accesses"] == 2 * TABLES * POOL


def test_replicated_placement_splits_traffic(dense_ref):
    """Each replica of a replicated table serves a batch slice: per-unit
    access counts stay consistent with the hint-valid clipping."""
    _, params = dense_ref
    pats = _pats()
    trace = _trace(pats)
    plc = _replicated_placement(estimate_table_loads(trace, DIM * 4))
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8),
                      trace=trace, placement=plc)
    with ebc.storage:
        ebc.storage.hint_valid(6)     # 2 padding rows out of 8
        ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=0)))
        st = ebc.storage.stats()
        # 6 valid queries x 6 tables x POOL accesses, replicas or not
        assert st["total_accesses"] == 6 * TABLES * POOL


def test_balanced_placement_requires_trace(dense_ref):
    _, params = dense_ref
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    with pytest.raises(ValueError, match="balanced.*trace"):
        ebc.storage.build(params, PSConfig(hot_rows=8),
                          placement="balanced")
    with pytest.raises(ValueError, match="placement"):
        ebc.storage.build(params, PSConfig(hot_rows=8),
                          placement="diagonal")
    # table-count mismatch is rejected
    bad = ShardPlacement.contiguous(TABLES + 1, 2)
    with pytest.raises(ValueError, match="tables"):
        ebc.storage.build(params, PSConfig(hot_rows=8), placement=bad)


def test_rejected_rebuild_leaves_live_backend_serving(dense_ref):
    """Regression: build() validates the placement BEFORE tearing down the
    old shards, so a rejected rebuild cannot silently kill prefetch."""
    ebc0, params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8,
                                       async_prefetch=True),
                      trace=_trace(pats), num_shards=2)
    with ebc.storage:
        with pytest.raises(ValueError, match="balanced.*trace"):
            ebc.storage.build(params, PSConfig(hot_rows=8),
                              placement="balanced")   # forgot trace=
        caps = ebc.storage.capabilities()
        assert caps.stageable and caps.async_prefetch   # workers alive
        idx = _batch(pats, 8, seed=0)
        got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
        want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
        assert np.array_equal(got, want)


def test_contiguous_placement_keeps_table_slices(dense_ref):
    """The legacy view survives for the legacy placement; balanced
    placements (generally non-contiguous) leave it empty."""
    _, params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params, PSConfig(hot_rows=8), num_shards=3)
    assert ebc.storage.table_slices[0].start == 0
    assert ebc.storage.table_slices[-1].stop == TABLES
    ebc.storage.build(params, PSConfig(hot_rows=8), num_shards=3,
                      trace=_trace(pats), placement="balanced")
    assert ebc.storage.placement.strategy == "balanced"
    ebc.storage.close()


# ---------------------------------------------------------------------------
# queue-depth controller
# ---------------------------------------------------------------------------

def test_controller_never_leaves_bound_and_converges():
    ctl = QueueDepthController(min_depth=1, max_depth=6)

    # synthetic plant: overlap improves with depth, saturating at depth 4
    def plant(depth):
        return min(1.0, 0.25 * depth)

    depth = 1
    seen = []
    for _ in range(20):
        depth = ctl.propose(depth, plant(depth), peak_depth=depth)
        seen.append(depth)
        assert ctl.min_depth <= depth <= ctl.max_depth
    # converged: the last proposals are a fixed point inside the dead band
    assert len(set(seen[-5:])) == 1
    final = seen[-1]
    assert ctl.widen_below <= plant(final)


def test_controller_widen_narrow_hold():
    ctl = QueueDepthController(min_depth=1, max_depth=4,
                               widen_below=0.5, narrow_above=0.95)
    assert ctl.propose(2, 0.1, peak_depth=2) == 3        # widen
    assert ctl.propose(4, 0.1, peak_depth=4) == 4        # clamped at max
    assert ctl.propose(3, 1.0, peak_depth=1) == 2        # narrow: unused
    assert ctl.propose(3, 1.0, peak_depth=3) == 3        # full queue: hold
    assert ctl.propose(2, 0.7, peak_depth=2) == 2        # dead band: hold
    assert ctl.propose(1, 1.0, peak_depth=0) == 1        # clamped at min
    assert ctl.propose(2, None, peak_depth=0) == 2       # idle: hold
    assert ctl.propose(99, 0.7, peak_depth=0) == 4       # clamp on entry
    with pytest.raises(ValueError):
        QueueDepthController(min_depth=0)
    with pytest.raises(ValueError):
        QueueDepthController(widen_below=0.9, narrow_above=0.5)


def test_prefetcher_set_depth_runtime():
    """Depth moves never drop staged work; zero disables staging."""
    from repro.ps.prefetch import PrefetchQueue, StagedBatch
    q = PrefetchQueue(depth=2, resolver=lambda t, rows: np.zeros(
        (len(rows), 2), np.float32))

    def mk(seed):
        idx = np.full((1, 1, 2), seed, np.int64)
        return StagedBatch(idx, {0: np.arange(2, dtype=np.int64)}, {})

    assert q.stage(mk(0)) and q.stage(mk(1))
    assert not q.can_stage()
    q.set_depth(1)                       # shrink below current occupancy
    assert len(q) == 2                   # nothing dropped
    assert not q.can_stage()
    assert q.consume(np.full((1, 1, 2), 0, np.int64)) is not None
    assert q.consume(np.full((1, 1, 2), 1, np.int64)) is not None
    assert q.can_stage()
    q.set_depth(0)
    assert not q.can_stage()


# ---------------------------------------------------------------------------
# ParameterServer tier resize / retune
# ---------------------------------------------------------------------------

def test_resize_tiers_stays_bit_exact():
    pats = _pats()
    rng = np.random.default_rng(0)
    tables = rng.normal(size=(TABLES, ROWS, DIM)).astype(np.float32)
    ps = ParameterServer(tables, PSConfig(hot_rows=16, warm_slots=16,
                                          window_batches=4),
                         trace=_trace(pats))
    idx = _batch(pats, 8, seed=0)
    want = tables[np.arange(TABLES)[None, :, None], idx]
    assert np.array_equal(ps.lookup(idx), want)
    ps.resize_tiers(48, 8)               # grow hot, shrink warm
    assert ps.cfg.hot_rows == 48 and ps.num_hot == 48
    assert np.array_equal(ps.lookup(idx), want)
    ps.resize_tiers(0, 64)               # hot off entirely
    assert ps.num_hot == 0
    assert np.array_equal(ps.lookup(idx), want)


def test_retune_plans_from_window_and_respects_budget():
    pats = _pats()
    ps = ParameterServer(np.zeros((TABLES, ROWS, DIM), np.float32),
                         PSConfig(hot_rows=4, warm_slots=4,
                                  window_batches=8))
    assert ps.retune(1 << 20) is None    # empty window: nothing to plan
    for s in range(4):
        ps.lookup(_batch(pats, 8, seed=s))
    budget = 64 * 1024
    result = ps.retune(budget)
    assert result is not None
    cap = ps.cfg.capacity_rows()
    assert TABLES * cap * DIM * 4 <= budget
    assert cap > 8                       # the budget allows growth


# ---------------------------------------------------------------------------
# session auto-tuning loop (and the device backend staying inert)
# ---------------------------------------------------------------------------

def _session_model(storage):
    model = DLRM(DLRMConfig(embedding=_stage_cfg(storage),
                            bottom_mlp=(32, DIM), top_mlp=(16, 1)))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.mark.parametrize("backend,build_kw", [
    ("tiered", {}), ("sharded", {"num_shards": 2})])
def test_session_auto_tunes_depth_within_bounds(backend, build_kw):
    model, params = _session_model(backend)
    pats = _pats()
    model.ebc.storage.build(
        params, PSConfig(hot_rows=8, warm_slots=8, prefetch_depth=2,
                         async_prefetch=True, window_batches=4),
        trace=_trace(pats), **build_kw)
    assert model.ebc.storage.capabilities().tunable
    ctl = QueueDepthController(min_depth=1, max_depth=4)
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6,
                        auto_tune=AutoTuneConfig(depth_every_batches=2,
                                                 controller=ctl)) as sess:
        for b in range(10):
            dense = np.zeros((8, model.cfg.dense_features), np.float32)
            sess.submit_batch(dense, _batch(pats, 8, seed=b), qid0=b * 8)
            if b >= 1:
                sess.poll()
        sess.drain()
        pct = sess.percentiles()
    assert "prefetch_depth" in pct
    assert ctl.min_depth <= pct["prefetch_depth"] <= ctl.max_depth
    assert pct["depth_retunes"] == len(sess.tuner.events)
    for e in sess.tuner.events:          # every move stayed inside bounds
        assert ctl.min_depth <= e["to"] <= ctl.max_depth


def test_auto_tuner_never_reenables_disabled_staging():
    """Regression: prefetch_depth=0 is an operator decision; the tuner
    must not clamp it up to min_depth."""
    model, params = _session_model("tiered")
    pats = _pats()
    model.ebc.storage.build(
        params, PSConfig(hot_rows=8, warm_slots=8, prefetch_depth=0,
                         window_batches=4),
        trace=_trace(pats))
    assert model.ebc.storage.capabilities().tunable
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6,
                        auto_tune=AutoTuneConfig(depth_every_batches=2)
                        ) as sess:
        for b in range(6):
            dense = np.zeros((8, model.cfg.dense_features), np.float32)
            sess.submit_batch(dense, _batch(pats, 8, seed=b), qid0=b * 8)
            if b >= 1:
                sess.poll()
        sess.drain()
    assert sess.tuner.events == []
    assert model.ebc.storage.prefetch_depth() == 0


def test_auto_tuner_narrows_from_window_peak_not_lifetime_max():
    """Regression: narrowing must use the per-window queue peak — the
    lifetime max_queue_depth would block reclaiming dead slots forever
    after one burst."""
    from repro.ps.tuning import AutoTuner

    class FakeStorage:
        """Minimal tunable storage: full overlap, queue busy only in the
        first window."""

        def __init__(self):
            self.depth = 4
            self.ready = 0
            self.window_peaks = [4, 1, 1, 1]   # burst, then idle queue

        def capabilities(self):
            from repro.storage import StorageCapabilities
            return StorageCapabilities(tunable=True)

        def stats(self):
            self.ready += 10                   # all consumed buffers ready
            return {"consume_ready": self.ready, "consume_waited": 0}

        def prefetch_depth(self):
            return self.depth

        def set_prefetch_depth(self, d):
            self.depth = d
            return True

        def take_prefetch_window_peak(self):
            return self.window_peaks.pop(0) if self.window_peaks else 0

    store = FakeStorage()
    tuner = AutoTuner(AutoTuneConfig(
        depth_every_batches=1,
        controller=QueueDepthController(min_depth=1, max_depth=4)), store)
    tuner.step()                    # window peak 4 == depth: hold
    assert store.depth == 4
    tuner.step()                    # window peak 1 < depth: narrow
    assert store.depth == 3
    tuner.step()
    assert store.depth == 2


def test_auto_tuner_snapshot_postdates_warmup_reset():
    """Regression: a second session on a pre-used storage must not see the
    pre-warmup counters — negative deltas would fabricate an overlap."""
    model, params = _session_model("tiered")
    pats = _pats()
    model.ebc.storage.build(
        params, PSConfig(hot_rows=8, warm_slots=8, prefetch_depth=2,
                         async_prefetch=True, window_batches=4),
        trace=_trace(pats))
    # pre-use the storage so its consume counters are non-zero
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6) as s1:
        for b in range(4):
            dense = np.zeros((8, model.cfg.dense_features), np.float32)
            s1.submit_batch(dense, _batch(pats, 8, seed=b), qid0=b * 8)
            if b >= 1:
                s1.poll()
        s1.drain()
    model.ebc.storage.build(        # rebuild workers for the next session
        params, PSConfig(hot_rows=8, warm_slots=8, prefetch_depth=2,
                         async_prefetch=True, window_batches=4),
        trace=_trace(pats))
    sess = ServingSession(model, params,
                          batcher=BatcherConfig(max_batch=8,
                                                max_wait_s=0.0),
                          sla_ms=1e6,
                          auto_tune=AutoTuneConfig(depth_every_batches=2))
    try:
        # the tuner's baseline snapshot postdates the warmup stats reset
        assert sess.tuner._last == {"consume_ready": 0,
                                    "consume_waited": 0}
    finally:
        sess.close()


def test_auto_tuner_treats_nonpositive_delta_as_idle():
    from repro.ps.tuning import AutoTuner
    from repro.storage import StorageCapabilities

    class ResettingStorage:
        """consume counters that go DOWN mid-window (external reset)."""

        def __init__(self):
            self.depth = 2
            self.readings = [{"consume_ready": 50, "consume_waited": 0},
                             {"consume_ready": 0, "consume_waited": 0}]

        def capabilities(self):
            return StorageCapabilities(tunable=True)

        def stats(self):
            return self.readings.pop(0) if len(self.readings) > 1 \
                else self.readings[0]

        def prefetch_depth(self):
            return self.depth

        def set_prefetch_depth(self, d):
            self.depth = d
            return True

        def take_prefetch_window_peak(self):
            return 0

    store = ResettingStorage()
    tuner = AutoTuner(AutoTuneConfig(depth_every_batches=1), store)
    tuner.step()                 # delta = -50: idle window, no action
    assert tuner.events == [] and store.depth == 2


def test_take_window_peak_resets_between_windows():
    from repro.ps.prefetch import PrefetchQueue, StagedBatch
    q = PrefetchQueue(depth=4, resolver=lambda t, rows: np.zeros(
        (len(rows), 2), np.float32))

    def mk(seed):
        idx = np.full((1, 1, 2), seed, np.int64)
        return StagedBatch(idx, {0: np.arange(2, dtype=np.int64)}, {})

    q.stage(mk(0)); q.stage(mk(1))
    assert q.take_window_peak() == 2
    q.consume(np.full((1, 1, 2), 0, np.int64))
    q.consume(np.full((1, 1, 2), 1, np.int64))
    # new window starts from the occupancy at the last take (2), but the
    # reset baseline is the occupancy at call time
    assert q.take_window_peak() == 2   # baseline was len(q)==2 at reset
    assert q.take_window_peak() == 0   # queue empty since
    assert q.max_queue_depth == 2      # lifetime max untouched


def test_device_backend_ignores_tuning_hooks():
    """Regression: tuning on `device` is inert — hooks are no-ops, the
    session loop never errors, and no tuning keys leak into the report."""
    model, params = _session_model("device")
    store = model.ebc.storage
    assert not store.capabilities().tunable
    assert store.prefetch_depth() == 0
    assert store.set_prefetch_depth(7) is False
    assert store.prefetch_depth() == 0
    assert store.retune_capacities(1 << 30) is None
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6, auto_tune=True) as sess:
        assert sess.tuner is not None and not sess.tuner.enabled
        dense = np.zeros((8, model.cfg.dense_features), np.float32)
        sess.submit_batch(dense, _batch(_pats(), 8, seed=0))
        sess.drain()
        pct = sess.percentiles()
    assert sess.tuner.events == []
    assert "prefetch_depth" not in pct and "depth_retunes" not in pct


def test_capacity_retune_through_session():
    model, params = _session_model("tiered")
    pats = _pats()
    model.ebc.storage.build(
        params, PSConfig(hot_rows=4, warm_slots=4, window_batches=8),
        trace=_trace(pats))
    cfg = AutoTuneConfig(depth_every_batches=0, capacity_every_batches=3,
                         budget_fallback_bytes=64 * 1024 * TABLES,
                         budget_fraction=1.0)
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6, auto_tune=cfg) as sess:
        for b in range(8):
            dense = np.zeros((8, model.cfg.dense_features), np.float32)
            sess.submit_batch(dense, _batch(pats, 8, seed=b), qid0=b * 8)
            if b >= 1:
                sess.poll()
        sess.drain()
        pct = sess.percentiles()
        # capacities actually moved toward the (much larger) budget — read
        # before close(): a closed backend drops its server (PR-5 lifecycle
        # fix), so post-close reads of .ps are no longer a thing
        cap_rows = model.ebc.storage.ps.cfg.capacity_rows()
    caps = [e for e in sess.tuner.events if e["kind"] == "capacity"]
    assert caps and pct["capacity_retunes"] == len(caps)
    assert cap_rows > 8


def test_estimate_device_budget_fallback_and_stats():
    class FakeDev:
        def memory_stats(self):
            return {"bytes_limit": 1000, "bytes_in_use": 200}

    assert estimate_device_budget(fraction=0.5, device=FakeDev()) == 400

    class NoStats:
        def memory_stats(self):
            return None

    assert estimate_device_budget(fallback_bytes=123,
                                  device=NoStats()) == 123
    assert estimate_device_budget(device=NoStats()) is None


# ---------------------------------------------------------------------------
# the CI gate itself (tools/check_bench.py)
# ---------------------------------------------------------------------------

def _load_check_bench():
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_schema_vs_drift():
    cb = _load_check_bench()
    base = {("s", "a", "bit_exact"): True,
            ("s", "a", "p99_ms"): 10.0,
            ("s", "a", "hit"): 0.8,
            ("s", "a", "caps"): "stageable"}
    # identical -> clean
    errors, warnings = cb.compare(base, dict(base), 4.0, 0.5)
    assert errors == [] and warnings == []
    # timing drift -> warning only; bool flip / missing / type -> errors
    new = dict(base)
    new[("s", "a", "p99_ms")] = 100.0
    errors, warnings = cb.compare(base, new, 4.0, 0.5)
    assert not errors and len(warnings) == 1
    new = dict(base)
    new[("s", "a", "bit_exact")] = False
    del new[("s", "a", "caps")]
    new[("s", "a", "hit")] = "high"
    errors, _ = cb.compare(base, new, 4.0, 0.5)
    assert len(errors) == 3
    # the semantic placement invariant
    good = {("sharded_balance", "sharded_balance/balanced",
             "imbalance"): 1.4,
            ("sharded_balance", "sharded_balance/contiguous",
             "imbalance"): 1.0}
    errors, _ = cb.compare({}, good, 4.0, 0.5)
    assert any("not below contiguous" in e for e in errors)
    # the replica-routing invariant: routed must beat equal slicing on
    # both tail latency and slow-replica batch share
    bad_route = {("sharded_migration", "sharded_migration/route_aware",
                  "p99_ms"): 50.0,
                 ("sharded_migration", "sharded_migration/route_equal",
                  "p99_ms"): 40.0,
                 ("sharded_migration", "sharded_migration/route_aware",
                  "slow_frac"): 0.5,
                 ("sharded_migration", "sharded_migration/route_equal",
                  "slow_frac"): 0.5}
    errors, _ = cb.compare({}, bad_route, 4.0, 0.5)
    assert sum("replica routing regressed" in e for e in errors) == 2
    ok_route = {("sharded_migration", "sharded_migration/route_aware",
                 "p99_ms"): 20.0,
                ("sharded_migration", "sharded_migration/route_equal",
                 "p99_ms"): 40.0,
                ("sharded_migration", "sharded_migration/route_aware",
                 "slow_frac"): 0.05,
                ("sharded_migration", "sharded_migration/route_equal",
                 "slow_frac"): 0.5}
    errors, _ = cb.compare({}, ok_route, 4.0, 0.5)
    assert errors == []
