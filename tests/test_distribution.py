"""Distribution tests: MoE EP == dense-dispatch numerics, sharding rules,
dry-run lower+compile on a small debug mesh (subprocess: forced device count).
"""
import numpy as np
import pytest


def test_moe_ep_matches_dense(multidevice):
    """EP all-to-all path under shard_map must equal the single-shard dense
    dispatch bit-for-bit (same routing, same capacity)."""
    out = multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.models.moe import MoEContext, moe_ffn_local, moe_init
import dataclasses

cfg = dataclasses.replace(reduced(get_config("deepseek-v2-lite-16b")),
                          moe_capacity_factor=8.0)
rng = jax.random.PRNGKey(0)
params = moe_init(rng, cfg)
T = 64
x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                      jnp.float32).astype(cfg.jnp_dtype)
dense_out = moe_ffn_local(params, cfg, x, None)

mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("model",))
ep = MoEContext(ep_axis="model", ep_size=4)

from repro.utils import shard_map_compat

@shard_map_compat(mesh=mesh,
               in_specs=({"router": P(), "wi": P("model"), "wg": P("model"),
                          "wo": P("model"), "shared": P()}, P("model")),
               out_specs=P("model"), check_vma=False)
def run(p, xs):
    return moe_ffn_local(p, cfg, xs, ep)

ep_out = run(params, x)
err = float(jnp.abs(ep_out.astype(jnp.float32)
                    - dense_out.astype(jnp.float32)).max())
print("MAXERR", err)
assert err < 1e-2, err
""", ndev=4)
    assert "MAXERR" in out


def test_moe_drop_stats():
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models.moe import moe_aux_stats, moe_init

    cfg = dataclasses.replace(reduced(get_config("llama4-scout-17b-a16e")),
                              moe_capacity_factor=0.5)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, cfg.d_model))
    stats = moe_aux_stats(params, cfg, x.astype(cfg.jnp_dtype))
    assert 0.0 < float(stats["drop_rate"]) < 1.0  # tight capacity must drop
    assert float(stats["max_load"]) >= 1.0


def test_param_specs_rules(multidevice):
    out = multidevice("""
import jax, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs import get_config
from repro.launch.sharding import param_specs
from repro.models import build_model

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("phi4-mini-3.8b")
model = build_model(cfg)
abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
specs = param_specs(abstract, mesh)
flat = dict(jax.tree_util.tree_flatten_with_path(
    specs, is_leaf=lambda x: isinstance(x, P))[0])
by_name = {"/".join(str(getattr(k, "key", getattr(k, "name", k)))
                    for k in path): v for path, v in flat.items()}
embed = by_name["embed"]
assert embed[0] == "model", embed          # vocab over TP
groups_wq = [v for k, v in by_name.items() if k.endswith("mixer/wq")][0]
assert groups_wq[0] is None                 # stacked group dim unsharded
assert groups_wq[-1] == "model"             # columns over TP
norm = [v for k, v in by_name.items() if k.endswith("norm1")][0]
assert all(a is None for a in norm)
# every spec divides its dim
leaves = jax.tree_util.tree_leaves(abstract)
specs_l = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
for leaf, spec in zip(leaves, specs_l):
    for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
        if ax is None: continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0, (leaf.shape, spec)
print("SPECS_OK")
""", ndev=8)
    assert "SPECS_OK" in out


@pytest.mark.parametrize("arch,kind", [
    ("deepseek-v2-lite-16b", "train"),
    ("jamba-1.5-large-398b", "decode"),
    ("gemma3-27b", "prefill"),
    ("whisper-medium", "decode"),
])
def test_debug_mesh_lower_compile(multidevice, arch, kind):
    """Reduced-config version of the production dry-run on a (2,4) mesh."""
    out = multidevice(f"""
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config, reduced
from repro.models.config import ShapeConfig
from repro.launch.steps import make_step
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = reduced(get_config("{arch}"))
shp = ShapeConfig("t", 64, 8, "{kind}")
b = make_step(cfg, shp, mesh)
c = jax.jit(b.fn, in_shardings=b.in_shardings, out_shardings=b.out_shardings,
            donate_argnums=b.donate_argnums).lower(*b.inputs).compile()
from repro.roofline.analyze import xla_cost_analysis
assert xla_cost_analysis(c).get("flops", 0) > 0
print("LOWERED_OK")
""", ndev=8)
    assert "LOWERED_OK" in out


def test_gradient_compression_error_feedback():
    import jax.numpy as jnp
    from repro.optim import apply_error_feedback, compress_grads
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    comp, resid = compress_grads(g)
    assert comp["w"].dtype == jnp.bfloat16
    # error feedback recovers what compression lost
    recovered = apply_error_feedback(
        {"w": comp["w"].astype(jnp.float32)}, resid)
    np.testing.assert_allclose(np.asarray(recovered["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)


def test_vocab_parallel_loss_matches_unsharded(multidevice):
    """The vocab-parallel xent path (§Perf A3) is numerically identical to
    the single-device loss."""
    out = multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config, reduced
from repro.models import build_model

cfg = reduced(get_config("phi4-mini-3.8b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
ref = float(model.loss(params, toks, toks))

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
got = float(jax.jit(lambda p, t: model.loss(p, t, t, mesh=mesh))(params, toks))
print("LOSSES", ref, got)
assert abs(ref - got) < 1e-3 * max(1.0, abs(ref)), (ref, got)
""", ndev=8)
    assert "LOSSES" in out
