"""Live shard migration + load-aware replica routing (PR 5).

Pins the acceptance contract: a mid-stream migration — planner-triggered
or auto-tuner-driven — serves bit-identical lookups to the dense gather
before, during, and after the build-before-teardown swap; a failed or
rejected migration (and a failed rebuild) always leaves the old backend
serving; replica routing shifts batch slices away from a synthetically
slow replica while staying an exact partition; and the serving-lifecycle
bugfixes hold: closed backends raise clear errors and drop `tunable`,
`_chunk_bounds` follows its documented `np.array_split` law, merged
`queue_depth` is a per-shard max, and `ServingSession.submit_batch`
auto-advances query ids.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern, plan_shard_migration)
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import AutoTuneConfig, PSConfig
from repro.serving import BatcherConfig, ServingSession
from repro.storage import (MigrationPlan, ReplicaRouter, ShardPlacement,
                           estimate_table_loads, plan_migration,
                           plan_shard_placement)
from repro.storage.sharded import _chunk_bounds, merge_shard_stats

ROWS, TABLES, DIM, POOL = 256, 6, 16, 6
# heavy tables stacked at one end => the contiguous split starts lopsided
SKEWED = ("one_item", "one_item", "high_hot", "med_hot", "random", "random")


def _pats(hotness=SKEWED):
    return [make_pattern(h, ROWS, seed=t) for t, h in enumerate(hotness)]


def _batch(pats, batch, seed):
    return np.stack([p.sample(batch, POOL, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _trace(pats, batches=3, batch=8, seed0=50):
    return np.concatenate([_batch(pats, batch, seed0 + s)
                           for s in range(batches)], axis=0)


def _stage_cfg(storage="device"):
    return EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla",
                                storage=storage)


@pytest.fixture(scope="module")
def dense_ref():
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    params = ebc.init(jax.random.PRNGKey(0))
    return ebc, params


def _build_sharded(params, pats, **kw):
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    kw.setdefault("num_shards", 2)
    ebc.storage.build(params,
                      PSConfig(hot_rows=16, warm_slots=16,
                               async_prefetch=True, window_batches=8),
                      trace=_trace(pats), **kw)
    return ebc


# ---------------------------------------------------------------------------
# migration planning (placement level)
# ---------------------------------------------------------------------------

def test_plan_migration_threshold_and_gain_gates():
    pats = _pats()
    trace = _trace(pats)
    loads = estimate_table_loads(trace, DIM * 4)
    cont = ShardPlacement.contiguous(TABLES, 2, loads=loads)
    assert cont.imbalance_ratio() > 1.2          # the mix really is skewed
    mig = plan_migration(cont, trace, row_bytes=DIM * 4, threshold=1.1)
    assert isinstance(mig, MigrationPlan)
    assert mig.imbalance_after < mig.imbalance_before
    assert mig.moved_tables                       # something actually moves
    assert mig.imbalance_before == pytest.approx(cont.imbalance_ratio())
    # above-threshold serving placement: no plan
    assert plan_migration(cont, trace, row_bytes=DIM * 4,
                          threshold=10.0) is None
    # an already-balanced placement never migrates (gain gate)
    bal = plan_shard_placement(trace, 2, row_bytes=DIM * 4)
    assert plan_migration(bal, trace, row_bytes=DIM * 4,
                          threshold=1.0) is None
    # single shard: nothing to balance
    one = ShardPlacement.contiguous(TABLES, 1, loads=loads)
    assert plan_migration(one, trace, row_bytes=DIM * 4) is None
    # the planner-API offline entry answers the same what-if
    assert plan_shard_migration(cont, trace, row_bytes=DIM * 4,
                                threshold=1.1).moved_tables \
        == mig.moved_tables


def test_plan_migration_can_change_replica_count():
    loads = np.array([100.0, 5.0, 5.0, 5.0])
    old = ShardPlacement(num_tables=4, num_shards=3,
                         replicas=((0,), (1,), (2,), (0,)),
                         loads=tuple(np.ones(4)))
    mig = plan_migration(old, None, loads=loads, threshold=1.2,
                         replicate_factor=1.0)
    assert mig is not None
    assert 0 in mig.replica_changes               # table 0 gained replicas
    assert len(mig.new.replicas[0]) > 1


# ---------------------------------------------------------------------------
# mid-stream migration: bit-exact before / during / after the swap
# ---------------------------------------------------------------------------

def test_migration_mid_stream_bit_exact(dense_ref):
    ebc0, params = dense_ref
    pats = _pats()
    ebc = _build_sharded(params, pats, placement="contiguous",
                         migration_threshold=1.1)
    st = ebc.storage

    def check(seed):
        idx = _batch(pats, 8, seed=seed)
        got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
        want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
        assert np.array_equal(got, want), seed

    with st:
        for seed in range(4):                    # before (fills the window)
            st.stage(_batch(pats, 8, seed=seed + 1))
            check(seed)
        old_units = list(st.shards)
        plan = st.plan_migration()
        assert plan is not None                  # skew crossed the threshold
        check(4)                                 # during: plan in hand,
        #                                          old placement still serves
        res = st.install_migration(plan)
        assert res["migrated"] and res["imbalance_after"] \
            < res["imbalance_before"]
        assert st.placement.strategy == "balanced"
        assert all(ps.prefetch.closed for ps in old_units
                   if hasattr(ps.prefetch, "closed"))   # orphans joined
        for seed in range(5, 9):                 # after the swap
            st.stage(_batch(pats, 8, seed=seed + 1))
            check(seed)
        # counter invariant survives the new unit set
        s = st.stats()
        assert (s["hot_hits"] + s["warm_hits"] + s["cold_misses"]
                == s["total_accesses"])


def test_migration_via_plan_install_refresh(dense_ref):
    """`plan_refresh` carries the migration when a threshold is armed —
    placement re-planning at refresh time."""
    ebc0, params = dense_ref
    pats = _pats()
    ebc = _build_sharded(params, pats, placement="contiguous",
                         migration_threshold=1.1)
    st = ebc.storage
    with st:
        for seed in range(4):
            ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=seed)))
        plan = st.plan_refresh()
        assert plan["migration"] is not None
        res = st.install_refresh(plan)
        assert res["replanned"] and res["migrated"]
        assert st.placement.strategy == "balanced"
        idx = _batch(pats, 8, seed=9)
        assert np.array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            np.asarray(ebc0.apply(params, jnp.asarray(idx))))


def test_migration_via_auto_tuner(dense_ref):
    """The `migrate_every_batches` leg drives the whole loop through
    protocol verbs: traffic -> threshold crossing -> live swap."""
    _, params = dense_ref
    pats = _pats()
    model = DLRM(DLRMConfig(embedding=_stage_cfg("sharded"),
                            bottom_mlp=(32, DIM), top_mlp=(16, 1)))
    params = model.init(jax.random.PRNGKey(0))
    model.ebc.storage.build(
        params, PSConfig(hot_rows=16, warm_slots=16, async_prefetch=True,
                         window_batches=8),
        trace=_trace(pats), num_shards=2, placement="contiguous")
    assert model.ebc.storage.capabilities().migratable
    cfg = AutoTuneConfig(depth_every_batches=0, migrate_every_batches=3,
                         migrate_threshold=1.1)
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6, auto_tune=cfg) as sess:
        for b in range(8):
            dense = np.zeros((8, model.cfg.dense_features), np.float32)
            sess.submit_batch(dense, _batch(pats, 8, seed=b))
            if b >= 1:
                sess.poll()
        sess.drain()
        pct = sess.percentiles()
    migs = [e for e in sess.tuner.events if e["kind"] == "migration"]
    assert len(migs) >= 1
    assert pct["migrations"] == len(migs)
    assert migs[0]["imbalance_after"] < migs[0]["imbalance_before"]
    assert model.ebc.storage.placement.strategy == "balanced"


def test_device_backend_ignores_migration_hooks():
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    assert not ebc.storage.capabilities().migratable
    assert ebc.storage.update_routing() is None
    assert ebc.storage.plan_migration() is None
    assert ebc.storage.install_migration(None) == {"migrated": False}


# ---------------------------------------------------------------------------
# rejected / failed migration and rebuild: old backend keeps serving
# ---------------------------------------------------------------------------

def _failing_ps(monkeypatch, fail_after: int):
    """Make ParameterServer constructions fail after `fail_after` more
    successes (models a bad trace shape / OOM mid-construction)."""
    import repro.ps as ps_pkg
    real = ps_pkg.ParameterServer
    count = {"n": 0}

    class Flaky(real):
        def __init__(self, *a, **kw):
            if count["n"] >= fail_after:
                raise MemoryError("synthetic constructor failure")
            count["n"] += 1
            super().__init__(*a, **kw)

    monkeypatch.setattr(ps_pkg, "ParameterServer", Flaky)
    return count


def test_failed_migration_rolls_back(dense_ref, monkeypatch):
    ebc0, params = dense_ref
    pats = _pats()
    ebc = _build_sharded(params, pats, placement="contiguous",
                         migration_threshold=1.1)
    st = ebc.storage
    with st:
        for seed in range(4):
            ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=seed)))
        plan = st.plan_migration()
        assert plan is not None
        old_placement, old_units = st.placement, list(st.shards)
        _failing_ps(monkeypatch, fail_after=1)   # second new unit explodes
        with pytest.raises(MemoryError):
            st.install_migration(plan)
        # the old backend is untouched and still serving bit-exactly
        assert st.placement is old_placement
        assert st.shards == old_units
        assert st.capabilities().stageable       # workers alive
        idx = _batch(pats, 8, seed=9)
        assert np.array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            np.asarray(ebc0.apply(params, jnp.asarray(idx))))


def test_stale_migration_plan_rejected(dense_ref):
    """A plan raced by another placement change installs as a no-op."""
    _, params = dense_ref
    pats = _pats()
    ebc = _build_sharded(params, pats, placement="contiguous",
                         migration_threshold=1.1)
    st = ebc.storage
    with st:
        for seed in range(4):
            ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=seed)))
        plan = st.plan_migration()
        assert st.install_migration(plan)["migrated"]
        res = st.install_migration(plan)         # same plan, new placement
        assert res == {"migrated": False, "stale_plan": True}


def test_rebuild_ctor_failure_leaves_old_backend_serving(dense_ref,
                                                         monkeypatch):
    """Regression: build() used to close() the live shards BEFORE
    constructing the new servers, stranding a half-built backend."""
    ebc0, params = dense_ref
    pats = _pats()
    ebc = _build_sharded(params, pats)
    st = ebc.storage
    with st:
        _failing_ps(monkeypatch, fail_after=0)
        with pytest.raises(MemoryError):
            st.build(params, PSConfig(hot_rows=8, warm_slots=8),
                     trace=_trace(pats), num_shards=3)
        caps = st.capabilities()
        assert caps.stageable and caps.async_prefetch   # old workers alive
        assert st.num_shards == 2
        idx = _batch(pats, 8, seed=0)
        assert np.array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            np.asarray(ebc0.apply(params, jnp.asarray(idx))))


def test_tiered_rebuild_ctor_failure_leaves_old_serving(dense_ref,
                                                        monkeypatch):
    ebc0, params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("tiered"))
    ebc.storage.build(params, PSConfig(hot_rows=16, warm_slots=16,
                                       async_prefetch=True),
                      trace=_trace(pats))
    with ebc.storage:
        _failing_ps(monkeypatch, fail_after=0)
        with pytest.raises(MemoryError):
            ebc.storage.build(params, PSConfig(hot_rows=8))
        assert ebc.storage.capabilities().stageable
        idx = _batch(pats, 8, seed=0)
        assert np.array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            np.asarray(ebc0.apply(params, jnp.asarray(idx))))


# ---------------------------------------------------------------------------
# replica routing
# ---------------------------------------------------------------------------

def test_replica_router_equal_until_observed_and_partitions():
    r = ReplicaRouter(3)
    # equal split follows the np.array_split law
    assert list(r.bounds(8)) == [0, 3, 6, 8]
    assert list(r.bounds(9)) == [0, 3, 6, 9]
    assert not r.observe(np.full(3, np.nan))     # nothing served: no-op
    assert r.observe(np.array([1.0, 1.0, 8.0]))  # slow third replica
    f = r.fractions()
    assert f[2] < f[0] == pytest.approx(f[1])
    assert f.sum() == pytest.approx(1.0)
    for batch in (1, 2, 7, 32, 100):
        b = r.bounds(batch)
        assert b[0] == 0 and b[-1] == batch
        assert (np.diff(b) >= 0).all()           # monotone partition
    with pytest.raises(ValueError):
        ReplicaRouter(1)
    with pytest.raises(ValueError):
        r.observe(np.ones(2))


def test_replica_router_min_frac_floor_keeps_replica_observable():
    r = ReplicaRouter(2, min_frac=0.05)
    for _ in range(20):                          # pathologically slow #2
        r.observe(np.array([1.0, 1e6]))
    f = r.fractions()
    assert f[1] == pytest.approx(0.05 / 1.05, rel=1e-6) or f[1] >= 0.04
    assert r.bounds(100)[1] < 100                # replica 2 still gets rows


def test_replica_router_many_replicas_never_raises():
    """Regression: the default min_frac must clamp, not raise, at any
    replica count — router construction runs mid-swap in
    `_install_units`, where a raise would violate the rollback
    contract."""
    r = ReplicaRouter(32)
    assert r.min_frac <= 1.0 / 64 + 1e-12
    b = r.bounds(64)
    assert b[0] == 0 and b[-1] == 64
    with pytest.raises(ValueError, match="min_frac"):
        ReplicaRouter(2, min_frac=-0.1)


def test_replica_router_never_starves_a_replica_to_zero_rows():
    """Regression: rounding a tiny published fraction to a zero-width
    slice would freeze that replica's cost observations (no rows -> NaN
    cost) and starve it permanently. Whenever batch >= num_replicas,
    every replica keeps at least one row."""
    r = ReplicaRouter(2)
    for _ in range(20):                          # ~100x sustained cost gap
        r.observe(np.array([1.0, 100.0]))
    for batch in (2, 3, 8, 9, 32):
        widths = np.diff(r.bounds(batch))
        assert (widths >= 1).all(), (batch, list(widths))
        assert widths.sum() == batch
    # so the slow replica keeps producing observations and can recover
    for _ in range(20):
        r.observe(np.array([1.0, 1.0]))
    f = r.fractions()
    assert abs(f[0] - f[1]) < 0.2                # share won back


def test_session_mixed_submit_and_submit_batch_qids_unique():
    """Regression: submit() must advance the auto-qid counter too, or a
    following submit_batch() reuses its ids."""
    from repro.serving import Query
    model = DLRM(DLRMConfig(embedding=_stage_cfg("device"),
                            bottom_mlp=(32, DIM), top_mlp=(16, 1)))
    params = model.init(jax.random.PRNGKey(0))
    pats = _pats()
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=4, max_wait_s=0.0),
                        sla_ms=1e6) as sess:
        idx = _batch(pats, 4, seed=0)
        for i in range(4):
            sess.submit(Query(qid=i, dense=np.zeros(
                model.cfg.dense_features, np.float32), indices=idx[i]))
        sess.submit_batch(np.zeros((4, model.cfg.dense_features),
                                   np.float32), _batch(pats, 4, seed=1))
        qids = [q.qid for q in sess.server.batcher.queue]
        assert len(set(qids)) == len(qids) == 8
        sess.drain()


def test_replica_router_bounds_move_only_when_observe_says_so():
    """Regression: `bounds()` must be a pure function of the PUBLISHED
    split — a sub-tolerance EWMA drift that silently shifted a bound
    would strand staged batches cut at the old bounds in the bounded
    queues forever."""
    r = ReplicaRouter(2)
    for _ in range(12):                          # converge the EWMA
        r.observe(np.array([1.0, 3.0]))
    before = list(r.bounds(32))
    # a tiny drift: EWMA moves, published split must not
    assert not r.observe(np.array([1.0, 3.01]), tol=0.02)
    assert list(r.bounds(32)) == before
    # a big drift re-publishes
    assert r.observe(np.array([1.0, 30.0]))
    assert list(r.bounds(32)) != before


def _replicated_placement(loads):
    """Table 4 (heavy `random`) replicated across both shards."""
    return ShardPlacement(num_tables=TABLES, num_shards=2,
                          replicas=((0,), (0,), (1,), (1,),
                                    (0, 1), (0,)),
                          loads=tuple(float(x) for x in loads),
                          strategy="replicated")


def test_routing_shifts_load_off_slow_replica_bit_exact(dense_ref):
    """The tentpole routing contract: under a synthetically slow replica
    the router converges to a smaller slice for it, slices keep
    partitioning the batch, and lookups stay bit-exact throughout."""
    ebc0, params = dense_ref
    pats = _pats()
    trace = _trace(pats)
    plc = _replicated_placement(estimate_table_loads(trace, DIM * 4))
    ebc = _build_sharded(params, pats, placement=plc)
    st = ebc.storage
    with st:
        # replica k=1 of table 4 gets a per-row penalty (contended shard)
        slow = next(u for u in st._units
                    if u.chunk is not None and u.chunk[0] == 1)
        real_lookup = slow.ps.lookup

        def slow_lookup(idx):
            time.sleep(idx.shape[0] * 2e-4)
            return real_lookup(idx)
        slow.ps.lookup = slow_lookup

        t = int(slow.table_ids[0])
        for step in range(6):
            idx = _batch(pats, 16, seed=step)
            got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
            want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
            assert np.array_equal(got, want), step
            if step % 2 == 1:
                st.update_routing()
        frac = st._routers[t].fractions()
        assert frac[1] < 0.5 < frac[0]           # load moved off the slow one
        b = st._routers[t].bounds(16)
        assert b[0] == 0 and b[-1] == 16
        # and the routed backend still serves bit-exactly
        idx = _batch(pats, 16, seed=99)
        assert np.array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            np.asarray(ebc0.apply(params, jnp.asarray(idx))))


def test_routing_update_flushes_stale_staged_batches(dense_ref):
    """A routing move re-cuts future batches; staged batches cut at the
    old bounds must be dropped, not left pinning queue slots forever."""
    _, params = dense_ref
    pats = _pats()
    trace = _trace(pats)
    plc = _replicated_placement(estimate_table_loads(trace, DIM * 4))
    ebc = _build_sharded(params, pats, placement=plc)
    st = ebc.storage
    with st:
        slow = next(u for u in st._units
                    if u.chunk is not None and u.chunk[0] == 1)
        real_lookup = slow.ps.lookup
        slow.ps.lookup = lambda idx: (time.sleep(idx.shape[0] * 2e-4),
                                      real_lookup(idx))[1]
        for step in range(4):                    # gather cost observations
            ebc.apply(params, jnp.asarray(_batch(pats, 16, seed=step)))
        assert st.stage(_batch(pats, 16, seed=50))     # cut at equal bounds
        replica_units = [u for u in st._units if u.chunk is not None]
        solo_units = [u for u in st._units if u.chunk is None]
        assert all(len(u.ps.prefetch) > 0 for u in st._units)
        res = st.update_routing()
        assert res is not None and res["changed"]
        # only the moved table's replica units are flushed; solo units'
        # slices never depend on routing, so their staged batches stay
        assert all(len(u.ps.prefetch) == 0 for u in replica_units)
        assert all(len(u.ps.prefetch) == 1 for u in solo_units)
        # and the retained staged batches are still consumable
        idx = _batch(pats, 16, seed=50)
        ebc.apply(params, jnp.asarray(idx))
        assert all(len(u.ps.prefetch) == 0 for u in solo_units)


def test_update_routing_none_without_replicas(dense_ref):
    _, params = dense_ref
    ebc = _build_sharded(params, _pats(), placement="contiguous")
    with ebc.storage:
        assert ebc.storage.update_routing() is None


# ---------------------------------------------------------------------------
# serving-lifecycle bugfixes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,build_kw", [
    ("tiered", {}), ("sharded", {"num_shards": 2})])
def test_closed_backend_raises_clear_error_and_drops_tunable(
        dense_ref, backend, build_kw):
    _, params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg(backend))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8,
                                       async_prefetch=True),
                      trace=_trace(pats), **build_kw)
    assert ebc.storage.capabilities().tunable
    ebc.storage.close()
    ebc.storage.close()                          # idempotent
    caps = ebc.storage.capabilities()
    assert not caps.tunable and not caps.stageable and not caps.migratable
    idx = np.zeros((2, TABLES, POOL), np.int32)
    with pytest.raises(RuntimeError, match="closed.*build"):
        ebc.storage.lookup(params, idx)
    with pytest.raises(RuntimeError, match="closed.*build"):
        ebc.storage.stage(idx)
    assert ebc.storage.can_stage() is False
    # build() re-opens the backend
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8),
                      trace=_trace(pats), **build_kw)
    assert ebc.storage.capabilities().tunable
    ebc.storage.lookup(params, idx)
    ebc.storage.close()


def test_never_built_error_still_mentions_build():
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    with pytest.raises(RuntimeError, match="build"):
        ebc.apply({}, jnp.zeros((2, TABLES, POOL), jnp.int32))


def test_chunk_bounds_matches_array_split_law():
    """Regression: B=5, n=2 must split (3, 2) like np.array_split — the
    old linspace truncation produced (2, 3) against its own docstring."""
    assert [_chunk_bounds(5, 2, k) for k in range(2)] == [(0, 3), (3, 5)]
    for batch in (0, 1, 5, 7, 16, 33):
        for n in (1, 2, 3, 5):
            want = np.array_split(np.arange(batch), n)
            got = [_chunk_bounds(batch, n, k) for k in range(n)]
            assert [hi - lo for lo, hi in got] == [len(w) for w in want]
            assert got[0][0] == 0 and got[-1][1] == batch
            assert all(a[1] == b[0] for a, b in zip(got, got[1:]))


def test_merge_shard_stats_queue_depth_is_max_not_sum():
    """Regression: summing the instantaneous queue_depth gauge across
    shards inflated the merged report the auto-tuner reads."""
    a = {"total_accesses": 10, "hot_hits": 10, "warm_hits": 0,
         "cold_misses": 0, "queue_depth": 2, "max_queue_depth": 2}
    b = {"total_accesses": 10, "hot_hits": 10, "warm_hits": 0,
         "cold_misses": 0, "queue_depth": 1, "max_queue_depth": 3}
    m = merge_shard_stats([a, b])
    assert m["queue_depth"] == 2                 # per-shard max, not 3
    assert m["max_queue_depth"] == 3
    assert m["total_accesses"] == 20             # true counters still sum


def test_submit_batch_auto_advances_qids():
    """Regression: the old qid0=0 default made every batch reuse ids
    0..B-1, colliding in latency accounting."""
    model = DLRM(DLRMConfig(embedding=_stage_cfg("device"),
                            bottom_mlp=(32, DIM), top_mlp=(16, 1)))
    params = model.init(jax.random.PRNGKey(0))
    pats = _pats()
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=4, max_wait_s=0.0),
                        sla_ms=1e6) as sess:
        dense = np.zeros((4, model.cfg.dense_features), np.float32)
        sess.submit_batch(dense, _batch(pats, 4, seed=0))
        sess.submit_batch(dense, _batch(pats, 4, seed=1))
        qids = [q.qid for q in sess.server.batcher.queue]
        assert qids == list(range(8))            # no duplicates
        sess.submit_batch(dense, _batch(pats, 4, seed=2), qid0=100)
        sess.submit_batch(dense, _batch(pats, 4, seed=3))
        qids = [q.qid for q in sess.server.batcher.queue]
        assert qids[-8:] == list(range(100, 108))  # explicit re-base honours
        sess.drain()
        assert sess.stats.served == 16
