"""Zero-downtime online model updates (PR 10).

Pins the acceptance contract of the versioned-update stack:

  * every updatable backend (`device`, `tiered`, `sharded`, `pool`,
    tenant views) speaks begin/apply/commit/abort and serves the OLD
    version bit-exact until commit — buffered rows are invisible;
  * after commit, lookups are bit-exact against a dense-gather oracle
    holding the updated tables; abort restores cleanly and the version
    never advances;
  * the shared `UpdateTxn` plumbing enforces version monotonicity,
    one-open-transaction, geometry/dtype validation at apply time, and
    last-write-wins merge of repeated row applies;
  * a delta landing while a sharded migration plan is in flight commits
    correctly, and installing the (still-fresh) plan afterwards carries
    the new bytes — migration never rolls weights back;
  * pool commits are two-phase: a worker killed between apply and
    commit rolls the WHOLE update back (old version keeps serving,
    dead worker respawned), and the immediate retry succeeds;
  * tenant-scoped updates bump only their tenant's version and never
    disturb sibling tables;
  * the serving-session epoch guard: queries are pinned to the model
    version at ADMISSION, every served batch is single-version, and
    each response is bit-exact against the pinned version's snapshot
    run through the same jitted engine shapes.
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ModelUpdateStream
from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern)
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import PSConfig
from repro import serving

ROWS, TABLES, DIM, POOL = 256, 6, 16, 6
SKEWED = ("one_item", "one_item", "high_hot", "med_hot", "random", "random")


def _pats(hotness=SKEWED):
    return [make_pattern(h, ROWS, seed=t) for t, h in enumerate(hotness)]


def _batch(pats, batch, seed):
    return np.stack([p.sample(batch, POOL, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _trace(pats, batches=3, batch=8, seed0=50):
    return np.concatenate([_batch(pats, batch, seed0 + s)
                           for s in range(batches)], axis=0)


def _stage_cfg(storage="device"):
    return EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla",
                                storage=storage)


@pytest.fixture(scope="module")
def dense_ref():
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    params = ebc.init(jax.random.PRNGKey(0))
    return ebc, params


def _oracle_apply(ebc0, params, tables, idx):
    """Dense-gather reference at an explicit [T, R, D] snapshot."""
    padded = np.asarray(params["tables"]).copy()
    padded[:TABLES] = tables
    return np.asarray(ebc0.apply({"tables": jnp.asarray(padded)},
                                 jnp.asarray(idx)))


def _delta(rng, tables, n_tables=2, n_rows=5):
    """Random changed-rows payload + the updated oracle snapshot."""
    changed = {}
    want = tables.copy()
    for t in rng.choice(TABLES, size=n_tables, replace=False):
        rows = rng.choice(ROWS, size=n_rows, replace=False)
        vals = rng.normal(size=(n_rows, DIM)).astype(np.float32)
        changed[int(t)] = (rows, vals)
        want[int(t), rows] = vals
    return changed, want


# ---------------------------------------------------------------------------
# storage-level round trip: invisible -> commit bit-exact -> abort clean
# ---------------------------------------------------------------------------

def _build(kind, params, pats, **kw):
    ebc = EmbeddingBagCollection(_stage_cfg(kind))
    if kind == "device":
        ebc.storage.build(params)
        return ebc
    cfg = PSConfig(hot_rows=16, warm_slots=16, prefetch_depth=2)
    if kind == "sharded":
        kw.setdefault("num_shards", 2)
        kw.setdefault("trace", _trace(pats))
    elif kind == "pool":
        kw.setdefault("num_workers", 2)
        kw.setdefault("num_shards", 2)
        kw.setdefault("trace", _trace(pats))
    ebc.storage.build(params, cfg, **kw)
    return ebc


@pytest.mark.parametrize("kind", ["device", "tiered", "sharded", "pool"])
def test_update_invisible_then_commit_bit_exact(dense_ref, kind):
    ebc0, dense_params = dense_ref
    pats = _pats()
    rng = np.random.default_rng(0)
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    params = ebc.init(jax.random.PRNGKey(0))     # fresh: device path mutates
    ebc = _build(kind, params, pats)
    st = ebc.storage
    tables = np.asarray(params["tables"])[:TABLES].copy()
    idx = _batch(pats, 8, seed=1)

    assert st.capabilities().updatable
    assert st.version() == 0
    np.testing.assert_array_equal(
        np.asarray(ebc.apply(params, jnp.asarray(idx))),
        _oracle_apply(ebc0, dense_params, tables, idx))

    changed, want = _delta(rng, tables)
    st.begin_update(1)
    for t, (rows, vals) in changed.items():
        st.apply_update(t, rows, vals)
    # buffered rows are INVISIBLE until commit — old version still serves
    np.testing.assert_array_equal(
        np.asarray(ebc.apply(params, jnp.asarray(idx))),
        _oracle_apply(ebc0, dense_params, tables, idx))

    res = st.commit_update(1)
    assert res["updated"] and res["version"] == 1 and st.version() == 1
    np.testing.assert_array_equal(
        np.asarray(ebc.apply(params, jnp.asarray(idx))),
        _oracle_apply(ebc0, dense_params, want, idx))

    # abort: buffered rows dropped, version pinned, serving untouched
    changed2, _ = _delta(rng, want)
    st.begin_update(2)
    for t, (rows, vals) in changed2.items():
        st.apply_update(t, rows, vals)
    assert st.abort_update(2) is True
    assert st.abort_update(2) is False           # idempotent when closed
    assert st.version() == 1
    np.testing.assert_array_equal(
        np.asarray(ebc.apply(params, jnp.asarray(idx))),
        _oracle_apply(ebc0, dense_params, want, idx))
    if hasattr(st, "close"):
        st.close()


def test_update_txn_guards():
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("tiered"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc.storage.build(params, PSConfig(hot_rows=16, warm_slots=16))
    st = ebc.storage
    with pytest.raises(ValueError, match="monotonic"):
        st.begin_update(0)
    with pytest.raises(RuntimeError, match="begin_update"):
        st.apply_update(0, np.array([0]), np.zeros((1, DIM), np.float32))
    with pytest.raises(RuntimeError, match="begin_update"):
        st.commit_update(1)
    st.begin_update(1)
    with pytest.raises(RuntimeError, match="already"):
        st.begin_update(2)
    with pytest.raises(ValueError, match="outside"):
        st.apply_update(TABLES, np.array([0]), np.zeros((1, DIM), np.float32))
    with pytest.raises(ValueError, match="outside"):
        st.apply_update(0, np.array([ROWS]), np.zeros((1, DIM), np.float32))
    with pytest.raises(ValueError, match="shape"):
        st.apply_update(0, np.array([0]), np.zeros((2, DIM), np.float32))
    with pytest.raises(ValueError, match="dtype"):
        st.apply_update(0, np.array([0]), np.zeros((1, DIM), np.float64))
    with pytest.raises(ValueError, match="does not match"):
        st.commit_update(7)
    assert st.version() == 0                      # nothing leaked through
    assert st.abort_update(1)


def test_update_last_write_wins(dense_ref):
    ebc0, dense_params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("tiered"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc.storage.build(params, PSConfig(hot_rows=16, warm_slots=16))
    st = ebc.storage
    tables = np.asarray(params["tables"])[:TABLES].copy()
    rng = np.random.default_rng(1)
    first = rng.normal(size=(3, DIM)).astype(np.float32)
    last = rng.normal(size=(2, DIM)).astype(np.float32)
    st.begin_update(1)
    st.apply_update(2, np.array([4, 5, 6]), first)
    st.apply_update(2, np.array([5, 6]), last)    # overwrites rows 5, 6
    st.apply_update(3, np.array([], np.int64),
                    np.zeros((0, DIM), np.float32))   # empty delta: legal
    res = st.commit_update(1)
    assert res["updated"] and res["tables"] == 1
    want = tables.copy()
    want[2, [4, 5, 6]] = first
    want[2, [5, 6]] = last
    idx = _batch(pats, 8, seed=2)
    np.testing.assert_array_equal(
        np.asarray(ebc.apply(params, jnp.asarray(idx))),
        _oracle_apply(ebc0, dense_params, want, idx))


# ---------------------------------------------------------------------------
# sharded: delta during an in-flight migration plan
# ---------------------------------------------------------------------------

def test_sharded_update_during_inflight_migration(dense_ref):
    ebc0, dense_params = dense_ref
    pats = _pats()
    rng = np.random.default_rng(2)
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc.storage.build(params,
                      PSConfig(hot_rows=16, warm_slots=16,
                               async_prefetch=True, window_batches=8),
                      trace=_trace(pats), num_shards=2,
                      placement="contiguous", migration_threshold=1.1)
    st = ebc.storage
    tables = np.asarray(params["tables"])[:TABLES].copy()
    with st:
        for seed in range(4):
            st.stage(_batch(pats, 8, seed=seed + 1))
            np.asarray(ebc.apply(params, jnp.asarray(_batch(pats, 8,
                                                            seed=seed))))
        plan = st.plan_migration()
        assert plan is not None                  # skew crossed the threshold
        # the delta lands while the plan is in hand
        changed, want = _delta(rng, tables)
        st.begin_update(1)
        for t, (rows, vals) in changed.items():
            st.apply_update(t, rows, vals)
        assert st.commit_update(1)["updated"] and st.version() == 1
        idx = _batch(pats, 8, seed=9)
        np.testing.assert_array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            _oracle_apply(ebc0, dense_params, want, idx))
        # installing the pre-update plan must carry the NEW bytes — the
        # rebuilt units gather from the updated authoritative copy
        assert st.install_migration(plan)["migrated"]
        assert st.version() == 1                 # migration keeps the epoch
        np.testing.assert_array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            _oracle_apply(ebc0, dense_params, want, idx))


# ---------------------------------------------------------------------------
# pool: two-phase distributed commit + kill-rollback
# ---------------------------------------------------------------------------

def test_pool_worker_kill_between_apply_and_commit_rolls_back(dense_ref):
    ebc0, dense_params = dense_ref
    pats = _pats()
    rng = np.random.default_rng(3)
    ebc = EmbeddingBagCollection(_stage_cfg("pool"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc = _build("pool", params, pats)
    st = ebc.storage
    tables = np.asarray(params["tables"])[:TABLES].copy()
    idx = _batch(pats, 8, seed=3)
    try:
        changed, want = _delta(rng, tables)
        st.begin_update(1)
        for t, (rows, vals) in changed.items():
            st.apply_update(t, rows, vals)
        st._transports[0].kill()                 # dies between apply & commit
        res = st.commit_update(1)
        assert not res["updated"] and res["rolled_back"], res
        assert 0 in res["respawned_workers"], res
        assert st.version() == 0                 # old epoch keeps serving
        np.testing.assert_array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            _oracle_apply(ebc0, dense_params, tables, idx))
        # the immediate retry succeeds over the respawned worker
        st.begin_update(1)
        for t, (rows, vals) in changed.items():
            st.apply_update(t, rows, vals)
        res = st.commit_update(1)
        assert res["updated"] and st.version() == 1, res
        np.testing.assert_array_equal(
            np.asarray(ebc.apply(params, jnp.asarray(idx))),
            _oracle_apply(ebc0, dense_params, want, idx))
    finally:
        st.close()


# ---------------------------------------------------------------------------
# tenant-scoped updates: independent versions, sibling isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sharded", "pool"])
def test_tenant_scoped_update_isolated(kind):
    pats = _pats()
    rng = np.random.default_rng(4)
    ebc = EmbeddingBagCollection(_stage_cfg(kind))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc = _build(kind, params, pats, tenants={"a": 2, "b": 4})
    st = ebc.storage
    tables = np.asarray(params["tables"])[:TABLES].copy()
    idx_a = np.stack([pats[t].sample(4, POOL, seed=40 + t)
                      for t in range(2)], axis=1).astype(np.int32)
    idx_b = np.stack([pats[2 + t].sample(4, POOL, seed=60 + t)
                      for t in range(4)], axis=1).astype(np.int32)

    def ref(tb, start, idx):
        """Dense reference over a tenant's slice of the shared tables —
        same XLA gather+sum the backends run, so comparisons are exact."""
        n = idx.shape[1]
        cfg = EmbeddingStageConfig(num_tables=n, rows=ROWS, dim=DIM,
                                   pooling=idx.shape[2], storage="device")
        return np.asarray(EmbeddingBagCollection(cfg).apply(
            {"tables": jnp.asarray(tb[start:start + n])}, idx))
    try:
        # a tenanted backend refuses GLOBAL updates — scoping is explicit
        with pytest.raises(RuntimeError):
            st.begin_update(1)
        vals = rng.normal(size=(3, DIM)).astype(np.float32)
        st.tenant_begin_update("a", 1)
        st.tenant_apply_update("a", 1, np.array([5, 6, 7]), vals)
        res = st.tenant_commit_update("a", 1)
        assert res["updated"] and res["tenant"] == "a"
        assert st.tenant_version("a") == 1 and st.tenant_version("b") == 0
        tables[1, [5, 6, 7]] = vals              # tenant-local t1 == global t1
        np.testing.assert_allclose(
            np.asarray(st.tenant_lookup("a", idx_a)),
            ref(tables, 0, idx_a), rtol=0, atol=0)
        # sibling tables bit-identical to the untouched snapshot
        np.testing.assert_allclose(
            np.asarray(st.tenant_lookup("b", idx_b)),
            ref(tables, 2, idx_b), rtol=0, atol=0)
        # tenant abort: version pinned, nothing applied
        st.tenant_begin_update("b", 3)
        st.tenant_apply_update("b", 0, np.array([0]),
                               rng.normal(size=(1, DIM)).astype(np.float32))
        assert st.tenant_abort_update("b", 3) is True
        assert st.tenant_version("b") == 0
        np.testing.assert_allclose(
            np.asarray(st.tenant_lookup("b", idx_b)),
            ref(tables, 2, idx_b), rtol=0, atol=0)
    finally:
        if hasattr(st, "close"):
            st.close()


# ---------------------------------------------------------------------------
# serving session: epoch guard — per-qid pinning, single-version batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["device", "tiered", "sharded"])
def test_session_epoch_guard_bit_exact(kind):
    rng = np.random.default_rng(5)
    ecfg = EmbeddingStageConfig(num_tables=4, rows=64, dim=8, pooling=2,
                                storage=kind, backend="xla")
    cfg = DLRMConfig(dense_features=4, bottom_mlp=(16, 8), top_mlp=(8, 1),
                     embedding=ecfg)
    model = DLRM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tables0 = np.asarray(params["embedding"]["tables"])[:4].copy()
    if kind == "tiered":
        model.ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=16,
                                                 prefetch_depth=2))
    elif kind == "sharded":
        model.ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=16,
                                                 prefetch_depth=2),
                                num_shards=2)

    # oracle: dense device clone, replaying each pinned version through the
    # SAME engine shapes the session compiled (jit-vs-eager differs)
    omodel = DLRM(DLRMConfig(
        dense_features=4, bottom_mlp=(16, 8), top_mlp=(8, 1),
        embedding=EmbeddingStageConfig(num_tables=4, rows=64, dim=8,
                                       pooling=2, storage="device",
                                       backend="xla")))

    def engine_like(ptree, dense, idx):
        if kind == "device":
            jitted = jax.jit(lambda p, d, i: omodel.forward(p, d, i))
            return np.asarray(jitted(ptree, dense, idx))
        rest = jax.jit(
            lambda d, p: omodel.forward_from_pooled(ptree, d, p))
        pooled = omodel.ebc.apply(ptree["embedding"], idx)
        return np.asarray(rest(jnp.asarray(dense), pooled))

    with tempfile.TemporaryDirectory() as d:
        pub = ModelUpdateStream(d)
        pub.publish_full(tables0)            # v1: the base snapshot
        stream = ModelUpdateStream(d)        # consumer cursor starts at v1
        sess = serving.ServingSession(
            model, params,
            batcher=serving.BatcherConfig(max_batch=8, max_wait_s=0.0),
            controllers=serving.configure(
                updates=serving.UpdateConfig(stream=stream)))
        batches = []
        sess.server.on_batch = lambda b, s: batches.append(
            ([q.qid for q in b], s.copy()))

        snapshots = {0: tables0.copy(), 1: tables0.copy()}
        version_tables = tables0.copy()
        traffic = []
        for step in range(10):
            dense = rng.normal(size=(8, 4)).astype(np.float32)
            idx = rng.integers(0, 64, size=(8, 4, 2)).astype(np.int32)
            traffic.extend((dense[i], idx[i]) for i in range(8))
            sess.submit_batch(dense, idx)
            while sess.poll(force=True):
                pass
            if step in (3, 6):
                t = step % 4
                rows = rng.choice(64, size=5, replace=False)
                vals = rng.normal(size=(5, 8)).astype(np.float32)
                v = pub.publish_delta({t: (rows, vals)})
                version_tables[t, rows] = vals
                snapshots[v] = version_tables.copy()
        sess.drain()
        p = sess.percentiles()
        # the consumer joined at the v1 base, so exactly the two deltas apply
        assert p["updates_applied"] == 2 and p["model_version"] == 3, p
        assert p["updates_delta"] == 2 and p["updates_full"] == 0, p
        assert p["updates_rolled_back"] == 0, p

        checked = 0
        for qids, scores in batches:
            pins = {sess.version_of(q) for q in qids}
            assert len(pins) == 1, f"mixed-version batch: {pins}"
            dense = np.zeros((8, 4), np.float32)   # engine pads to max_batch
            idx = np.zeros((8, 4, 2), np.int32)
            for i, q in enumerate(qids):
                dense[i], idx[i] = traffic[q]
            op = dict(params)
            op["embedding"] = dict(params["embedding"])
            op["embedding"]["tables"] = jnp.asarray(snapshots[pins.pop()])
            ref = engine_like(op, dense, idx)[:len(qids)]
            np.testing.assert_array_equal(scores, ref)
            checked += len(qids)
        assert checked == len(traffic)
        sess.close()
