"""Docs stay wired: the CI link/syntax gate also runs under tier-1.

The pages must exist, be linked from the README, resolve every intra-repo
link (tools/check_docs.py), and name real symbols — a cheap spot-check
that the architecture/serving docs track the code they describe.
"""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_check_docs_passes():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(REPO)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


def test_check_docs_catches_broken_link(tmp_path):
    (tmp_path / "README.md").write_text("see [gone](docs/nope.md)\n")
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"),
         str(tmp_path)],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "broken link" in out.stdout


def test_readme_links_docs_pages():
    readme = (REPO / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/serving.md" in readme


def test_docs_name_real_symbols():
    arch = (REPO / "docs" / "architecture.md").read_text()
    serving = (REPO / "docs" / "serving.md").read_text()
    # paths named in the docs must exist
    for rel in ("src/repro/ps", "src/repro/core", "src/repro/serving",
                "src/repro/kernels/embedding_bag", "benchmarks/run.py",
                "examples/serve_dlrm.py"):
        assert (REPO / rel).exists(), rel
        assert rel.split("src/")[-1] in arch or rel in arch, rel
    # symbols named in the docs must import
    import repro.core as core
    import repro.ps as ps
    import repro.serving as serving_mod
    for name in ("AsyncPrefetcher", "PrefetchQueue", "DeviceWarmCache",
                 "WarmCache", "ParameterServer", "PSConfig", "ColdStore"):
        assert hasattr(ps, name), name
        assert name in arch or name in serving, name
    for name in ("plan_tier_capacities", "EmbeddingBagCollection"):
        assert hasattr(core, name), name
    assert hasattr(serving_mod, "InferenceServer")
    for knob in ("hot_rows", "warm_slots", "warm_backing", "async_prefetch",
                 "prefetch_depth", "window_batches", "freq_decay",
                 "eviction"):
        assert knob in serving, knob
        assert hasattr(ps.PSConfig(), knob), knob
