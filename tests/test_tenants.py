"""Multi-tenant serving (PR 9): N models over ONE shared storage pool.

Pins the acceptance contract of the tenant-aware API:

  * two tenants served concurrently from one shared sharded backend are
    each bit-exact against a fresh device-storage reference of the same
    model — sharing hot/warm/cold state never leaks values across the
    tenant namespaces;
  * whole-backend `lookup()` is undefined under tenancy (typed error) —
    traffic flows only through the per-tenant views;
  * storage stats are tenant-scoped (`{"tenants": ..., "shared": ...}`)
    and obey the merge law on the tenant axis: the shared report's
    counters are exactly the fold of the per-tenant reports, per-tenant
    counters keep the tier invariant, and device bytes sum;
  * the fair-share arbiter conserves the device budget (Σ per-tenant
    budgets <= the one shared budget), keeps depths inside
    [depth_min, depth_max], and skips SLO-engaged tenants' depth knob;
  * a flash-crowd tenant cannot starve a steady neighbor when the fair
    scheduler + arbiter are on (containment), and demonstrably does
    under the fifo/no-arbiter baseline — the `multi_tenant` bench
    invariant, in miniature on a virtual clock;
  * tenants attach/detach mid-serving on the sharded backend with
    siblings bit-exact throughout; pool tenancy is static (typed error);
  * the unified controller config (`configure()` -> ServingControllers)
    is equivalent to the legacy `auto_tune=`/`slo=` kwargs, passing both
    surfaces raises, and a plain session rejects an arbiter;
  * the PR 1-2 shims stay removed (`build_parameter_server`,
    `InferenceServer(ps=...)`).
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.core import EmbeddingBagCollection, EmbeddingStageConfig
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import AutoTuneConfig, PSConfig
from repro.ps.tuning import ArbiterConfig, BudgetArbiter
from repro.serving import (BatcherConfig, InferenceServer, ServingControllers,
                           ServingSession, SLOConfig, TenantManager,
                           TenantSpec, configure)
from repro.serving.config import resolve_controllers
from repro.storage import StorageCapabilities
from repro.traffic import VirtualClock, make_traffic, replay_tenants

ROWS, DIM = 400, 16


def _spec(name, tables, pooling, seed):
    ecfg = EmbeddingStageConfig(num_tables=tables, rows=ROWS, dim=DIM,
                                pooling=pooling, storage="device")
    cfg = DLRMConfig(dense_features=4, bottom_mlp=(32, DIM), top_mlp=(16, 1),
                     embedding=ecfg)
    model = DLRM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return TenantSpec(name=name, model=model, params=params), cfg


def _device_ref(cfg, params, dense, idx):
    """Fresh device-storage model — the bit-exact oracle for a tenant."""
    ref = DLRM(cfg)
    return np.asarray(ref.forward(
        jax.tree_util.tree_map(np.asarray, params), dense, idx))


def _manager(specs, **kw):
    kw.setdefault("backend", "sharded")
    kw.setdefault("batcher", BatcherConfig(max_batch=8, max_wait_s=0.002))
    kw.setdefault("num_shards", 2)
    kw.setdefault("ps_cfg", PSConfig(hot_rows=64, warm_slots=64))
    return TenantManager(specs, **kw)


def _query_batch(rng, cfg, batch=4):
    dense = rng.normal(size=(batch, cfg.dense_features)).astype(np.float32)
    idx = rng.integers(0, ROWS, size=(
        batch, cfg.embedding.num_tables,
        cfg.embedding.pooling)).astype(np.int32)
    return dense, idx


# ---------------------------------------------------------------------------
# bit-exactness on one shared backend
# ---------------------------------------------------------------------------

def test_two_tenants_bit_exact_on_shared_sharded_backend():
    spec_a, cfg_a = _spec("a", 3, 5, 0)
    spec_b, cfg_b = _spec("b", 5, 3, 1)
    rng = np.random.default_rng(0)
    with _manager([spec_a, spec_b]) as mgr:
        assert mgr.names == ["a", "b"]
        for _ in range(3):       # interleaved traffic shares the caches
            da, ia = _query_batch(rng, cfg_a)
            db, ib = _query_batch(rng, cfg_b)
            oa = np.asarray(spec_a.model.forward(spec_a.params, da, ia))
            ob = np.asarray(spec_b.model.forward(spec_b.params, db, ib))
            assert np.array_equal(oa, _device_ref(cfg_a, spec_a.params,
                                                  da, ia))
            assert np.array_equal(ob, _device_ref(cfg_b, spec_b.params,
                                                  db, ib))
        # whole-backend lookup is undefined under tenancy — typed error
        with pytest.raises(RuntimeError, match="tenancy"):
            mgr.shared.lookup({}, ib)
        # migration is the arbiter's job under tenancy
        assert mgr.shared.plan_migration() is None


def test_tenant_geometry_must_agree_on_shared_axes():
    spec_a, _ = _spec("a", 3, 5, 0)
    ecfg = EmbeddingStageConfig(num_tables=2, rows=ROWS, dim=DIM * 2,
                                pooling=5, storage="device")
    model = DLRM(DLRMConfig(dense_features=4, bottom_mlp=(32, DIM * 2),
                            top_mlp=(16, 1), embedding=ecfg))
    bad = TenantSpec(name="b", model=model,
                     params=model.init(jax.random.PRNGKey(1)))
    with pytest.raises(ValueError, match="dim"):
        _manager([spec_a, bad])
    with pytest.raises(ValueError, match="duplicate"):
        _manager([spec_a, dataclasses.replace(spec_a)])


# ---------------------------------------------------------------------------
# tenant-scoped stats schema + merge law on the tenant axis
# ---------------------------------------------------------------------------

def test_tenant_stats_schema_and_merge_law():
    spec_a, cfg_a = _spec("a", 3, 5, 0)
    spec_b, cfg_b = _spec("b", 5, 3, 1)
    rng = np.random.default_rng(1)
    with _manager([spec_a, spec_b]) as mgr:
        for _ in range(2):
            for spec, cfg in ((spec_a, cfg_a), (spec_b, cfg_b)):
                d, i = _query_batch(rng, cfg)
                spec.model.forward(spec.params, d, i)
        st = mgr.stats()
        assert set(st) == {"tenants", "shared"}
        assert sorted(st["tenants"]) == ["a", "b"]
        assert st["shared"]["num_tenants"] == 2
        for name, rep in st["tenants"].items():
            assert rep["tenant"] == name
            # tier-counter invariant holds per tenant
            assert (rep["hot_hits"] + rep["warm_hits"] + rep["cold_misses"]
                    == rep["total_accesses"])
        # merge law on the tenant axis: shared counters fold the tenants
        for key in ("total_accesses", "hot_hits", "warm_hits",
                    "cold_misses", "device_bytes"):
            assert st["shared"][key] == sum(
                t[key] for t in st["tenants"].values()), key
        # warmup traffic is per-tenant: both namespaces saw their batches
        assert st["tenants"]["a"]["total_accesses"] > 0
        assert st["tenants"]["b"]["total_accesses"] > 0
        # latency report mirrors the schema
        pct = mgr.percentiles()
        assert set(pct) == {"tenants", "shared"}
        assert pct["shared"]["scheduling"] == "fair"


def test_single_tenant_report_stays_flat():
    """The degenerate 1-tenant manager reports like a plain session —
    callers of the flat schema keep working unchanged."""
    spec_a, cfg_a = _spec("a", 3, 5, 0)
    with _manager([spec_a]) as mgr:
        rng = np.random.default_rng(2)
        d, i = _query_batch(rng, cfg_a)
        mgr.submit_batch("a", d, i)
        mgr.drain()
        pct = mgr.percentiles()
        assert "tenants" not in pct and pct["served"] == len(d)
        assert pct["num_tenants"] == 1


# ---------------------------------------------------------------------------
# arbiter: budget conservation, depth bounds, SLO handshake
# ---------------------------------------------------------------------------

class _ArbView:
    """Stub tenant view: the exact surface BudgetArbiter touches."""

    def __init__(self, accesses=0, depth=2):
        self.accesses = accesses
        self.depth = depth
        self.budgets = []

    def capabilities(self):
        return StorageCapabilities(tunable=True)

    def stats(self):
        return {"total_accesses": self.accesses}

    def retune_capacities(self, budget_bytes):
        self.budgets.append(int(budget_bytes))
        return {"budget_bytes": int(budget_bytes)}

    def prefetch_depth(self):
        return self.depth

    def set_prefetch_depth(self, depth):
        self.depth = int(depth)
        return True


def test_arbiter_conserves_budget_and_bounds_depths():
    views = {"a": _ArbView(), "b": _ArbView(), "c": _ArbView()}
    cfg = ArbiterConfig(every_batches=4, budget_fallback_bytes=999_983,
                        min_share=0.1, depth_min=1, depth_max=8)
    arb = BudgetArbiter(cfg, views)
    assert arb.enabled
    # skewed live demand: a flash crowd on "a"
    views["a"].accesses += 9_000
    views["b"].accesses += 2_000
    views["c"].accesses += 100
    for _ in range(4):
        arb.step()
    assert len(arb.events) == 1
    ev = arb.events[-1]
    # conservation: shares sum to 1 and each budget floors to int, so the
    # split can never overcommit the one shared budget
    assert sum(ev["budgets"].values()) <= ev["budget_bytes"]
    assert sum(ev["shares"].values()) == pytest.approx(1.0)
    # the flash tenant wins budget, the idle one floors at min_share
    assert ev["shares"]["a"] > ev["shares"]["b"] > ev["shares"]["c"]
    assert ev["shares"]["c"] >= cfg.min_share / (1 + 2 * cfg.min_share) - 1e-9
    for v in views.values():
        assert cfg.depth_min <= v.depth <= cfg.depth_max
    assert views["a"].depth > views["c"].depth
    # zero-demand interval: everyone equal, still conserved
    for _ in range(4):
        arb.step()
    ev = arb.events[-1]
    assert sum(ev["budgets"].values()) <= ev["budget_bytes"]
    assert ev["shares"]["a"] == pytest.approx(1 / 3)
    assert "arbiter_rounds" in arb.summary()


def test_arbiter_skips_engaged_tenants_depth():
    """An SLO-engaged tenant owns its depth knob — the arbiter retunes
    its capacity but leaves the depth alone (no controller tug-of-war,
    same contract as the PR-5 suspension handshake)."""
    views = {"a": _ArbView(depth=7), "b": _ArbView(depth=2)}
    cfg = ArbiterConfig(every_batches=1, budget_fallback_bytes=1 << 20,
                        depth_min=1, depth_max=8)
    arb = BudgetArbiter(cfg, views)
    views["b"].accesses += 1000           # all demand on b
    arb.step(engaged=frozenset(["a"]))
    assert views["a"].depth == 7          # untouched while engaged
    assert views["a"].budgets             # capacity still arbitrated
    assert "a" in arb.events[-1]["skipped_engaged"]


# ---------------------------------------------------------------------------
# noisy neighbor: containment is the scheduler + arbiter, not luck
# ---------------------------------------------------------------------------

def _noisy_run(scheduling, arbiter):
    spec_s, cfg_s = _spec("steady", 3, 4, 0)
    spec_f, cfg_f = _spec("flash", 3, 4, 1)
    base = 400.0
    streams = {
        "steady": make_traffic("steady", base_qps=base, dense_features=4,
                               num_tables=3, pooling=4, rows=ROWS,
                               seed=2).queries(60),
        "flash": make_traffic("flash", base_qps=base, dense_features=4,
                              num_tables=3, pooling=4, rows=ROWS,
                              spike_qps=100 * base, spike_start_s=0.02,
                              spike_len_s=0.08, seed=3).queries(240),
    }
    mgr = _manager(
        [spec_s, spec_f], scheduling=scheduling,
        batcher=BatcherConfig(max_batch=8, max_wait_s=0.004),
        controllers=configure(arbiter=arbiter), clock=VirtualClock())
    try:
        replay_tenants(mgr, streams, window_queries=32)
        pct = mgr.percentiles()
        return {n: pct["tenants"][n]["p99_ms"] for n in mgr.names}
    finally:
        mgr.close()


def test_noisy_neighbor_contained_by_fair_scheduling():
    """The bench invariant in miniature: on a virtual clock (latency =
    deterministic queue wait), the flash tenant's backlog inflates the
    steady tenant's p99 under fifo/no-arbiter, and fair scheduling + the
    arbiter contain it."""
    fair = _noisy_run("fair", ArbiterConfig(every_batches=8,
                                            budget_fallback_bytes=1 << 20))
    fifo = _noisy_run("fifo", None)
    # under fifo the steady tenant queues behind the whole flash backlog;
    # fair + arbiter keep its tail flat (the probe margin is ~4x — assert
    # 2x so jitter in the measured service cost can't flake the test)
    assert fair["steady"] < 0.5 * fifo["steady"], (fair, fifo)
    # containment, not starvation-swapping: the steady tenant's tail under
    # fair stays within the flash tenant's own tail
    assert fair["steady"] <= fair["flash"] + 1e-9


# ---------------------------------------------------------------------------
# elastic tenancy: attach/detach mid-serving (sharded), static (pool)
# ---------------------------------------------------------------------------

def test_tenant_add_remove_mid_serving_keeps_siblings_exact():
    spec_a, cfg_a = _spec("a", 3, 5, 0)
    spec_b, cfg_b = _spec("b", 5, 3, 1)
    rng = np.random.default_rng(3)
    with _manager([spec_a, spec_b]) as mgr:
        da, ia = _query_batch(rng, cfg_a)
        ra = _device_ref(cfg_a, spec_a.params, da, ia)
        assert np.array_equal(
            np.asarray(spec_a.model.forward(spec_a.params, da, ia)), ra)

        spec_c, cfg_c = _spec("c", 2, 4, 4)
        mgr.add_tenant(spec_c)
        assert mgr.names == ["a", "b", "c"]
        dc, ic = _query_batch(rng, cfg_c)
        assert np.array_equal(
            np.asarray(spec_c.model.forward(spec_c.params, dc, ic)),
            _device_ref(cfg_c, spec_c.params, dc, ic))
        # siblings bit-exact through the attach
        assert np.array_equal(
            np.asarray(spec_a.model.forward(spec_a.params, da, ia)), ra)
        st = mgr.stats()
        assert st["shared"]["num_tenants"] == 3

        mgr.remove_tenant("c")
        assert mgr.names == ["a", "b"]
        with pytest.raises(KeyError):
            mgr.session("c")
        db, ib = _query_batch(rng, cfg_b)
        assert np.array_equal(
            np.asarray(spec_b.model.forward(spec_b.params, db, ib)),
            _device_ref(cfg_b, spec_b.params, db, ib))


def test_duplicate_attach_rejected():
    spec_a, _ = _spec("a", 3, 5, 0)
    spec_b, _ = _spec("b", 5, 3, 1)
    with _manager([spec_a, spec_b]) as mgr:
        with pytest.raises(ValueError, match="already"):
            mgr.add_tenant(spec_b)


# ---------------------------------------------------------------------------
# controller-config unification
# ---------------------------------------------------------------------------

def test_configure_normalizes_and_aliases_match():
    at = AutoTuneConfig(depth_every_batches=8)
    slo = SLOConfig(target_p99_ms=25.0)
    ctl = configure(auto_tune=at, slo=slo)
    assert isinstance(ctl, ServingControllers)
    assert ctl.auto_tune is at and ctl.slo is slo and ctl.arbiter is None
    # boolean auto_tune sugar normalizes in the config, not the session
    assert configure(auto_tune=True).auto_tune == AutoTuneConfig()
    assert configure(auto_tune=False).auto_tune is None
    # legacy kwargs resolve to the identical spec
    legacy = resolve_controllers(None, at, slo, where="test")
    unified = resolve_controllers(configure(auto_tune=at, slo=slo),
                                  None, None, where="test")
    assert legacy == unified
    with pytest.raises(ValueError, match="both"):
        resolve_controllers(configure(slo=slo), None, slo, where="test")


def test_session_legacy_kwargs_equal_controllers_surface():
    def build(**kw):
        ecfg = EmbeddingStageConfig(num_tables=3, rows=ROWS, dim=DIM,
                                    pooling=4, storage="device")
        model = DLRM(DLRMConfig(dense_features=4, bottom_mlp=(32, DIM),
                                top_mlp=(16, 1), embedding=ecfg))
        params = model.init(jax.random.PRNGKey(0))
        return ServingSession(model, params,
                              batcher=BatcherConfig(max_batch=4,
                                                    max_wait_s=0.0), **kw)

    slo = SLOConfig(target_p99_ms=30.0)
    with build(slo=slo) as legacy, \
            build(controllers=configure(slo=slo)) as unified:
        assert legacy.slo is not None and unified.slo is not None
        assert legacy.slo.cfg == unified.slo.cfg
    with pytest.raises(ValueError, match="both"):
        build(slo=slo, controllers=configure(slo=slo))
    with pytest.raises(ValueError, match="arbiter"):
        build(controllers=configure(arbiter=ArbiterConfig()))


# ---------------------------------------------------------------------------
# shim removal riding along (PR 1-2 surfaces stay gone)
# ---------------------------------------------------------------------------

def test_removed_shims_stay_removed():
    assert not hasattr(EmbeddingBagCollection, "build_parameter_server")
    ecfg = EmbeddingStageConfig(num_tables=2, rows=8, dim=4, pooling=2)
    with pytest.raises(TypeError):
        EmbeddingBagCollection(ecfg, ps=object())
    with pytest.raises(TypeError):
        InferenceServer(lambda d, i: d, BatcherConfig(), ps=object())
