"""SLO overload serving: admission shedding, degraded mode, the ladder.

Covers the PR's acceptance contract: shed queries raise a TYPED
`QueryShedError` (never silently dropped) for both the queue bound and
the deadline budget; the request queue stays bounded under overload;
degraded (warm-cache-only) serving zero-fills exactly the cold misses,
reports the measured L2 accuracy delta, keeps the tier-counter invariant,
and restores bit-exact answers the moment it is switched off; the
degraded delta is monotone in cache hit rate; every non-shed answer under
a flash-crowd replay is bit-exact vs the dense gather; the SLO controller
climbs and descends its ladder with hysteresis; and a 2k-batch run with
both the SLO controller and the PR 4 queue-depth auto-tuner live shows no
depth tug-of-war (the suspension handshake).
"""
import types

import numpy as np
import jax
import pytest

from repro.core import EmbeddingStageConfig, make_pattern
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import ParameterServer, PSConfig
from repro.ps.tuning import AutoTuneConfig, AutoTuner, QueueDepthController
from repro.serving import (Batcher, BatcherConfig, Query, QueryShedError,
                           ServingSession, SLOConfig, SLOController,
                           windowed_p99_ms)
from repro.storage import StorageCapabilities
from repro.traffic import VirtualClock, make_traffic, replay

ROWS, TABLES, DIM, POOL = 256, 4, 32, 6


def _tables(seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(TABLES, ROWS, DIM)).astype(np.float32)


def _pats():
    return [make_pattern("med_hot", ROWS, seed=t) for t in range(TABLES)]


def _batch(pats, batch, seed):
    return np.stack([p.sample(batch, POOL, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _gather(tables, idx):
    """Dense-gather reference: rows [B, T, L, D] straight from the tables."""
    return tables[np.arange(TABLES).reshape(1, TABLES, 1), idx]


def _query(qid):
    return Query(qid=qid, dense=np.zeros(4, np.float32),
                 indices=np.zeros((TABLES, POOL), np.int32))


# ---------------------------------------------------------------------------
# typed admission rejections
# ---------------------------------------------------------------------------

def test_queue_full_shed_is_typed_not_silent():
    b = Batcher(BatcherConfig(max_batch=4, max_queue=2))
    b.submit(_query(0))
    b.submit(_query(1))
    with pytest.raises(QueryShedError) as ei:
        b.submit(_query(2))
    err = ei.value
    assert err.reason == "queue_full"
    assert err.qid == 2 and err.queue_len == 2
    assert "queue_full" in str(err)
    # nothing silently dropped: the queue still holds exactly the admitted
    # queries, and the loss is counted
    assert [q.qid for q in b.queue] == [0, 1]
    assert b.shed == 1 and b.shed_reasons["queue_full"] == 1


def test_deadline_shed_is_typed_and_carries_the_prediction():
    b = Batcher(BatcherConfig(max_batch=4, deadline_ms=5.0))
    for _ in range(8):
        b.observe_service(0.004)        # EWMA converges to 4ms per batch
    for i in range(8):                  # <= 1 full batch ahead: 4ms < 5ms
        b.submit(_query(i))
    with pytest.raises(QueryShedError) as ei:
        b.submit(_query(8))             # 2 full batches ahead: 8ms > 5ms
    err = ei.value
    assert err.reason == "deadline"
    assert err.predicted_wait_s == pytest.approx(2 * b.service_ewma_s)
    assert err.predicted_wait_s > 0.005
    assert b.shed_reasons["deadline"] == 1


def test_empty_queue_always_admits_even_with_slow_ewma():
    # one pathologically slow batch (compile, GC pause) must not wedge
    # admission shut: its own service is not queue wait, so with nothing
    # queued ahead the query is admitted and the EWMA can refresh
    b = Batcher(BatcherConfig(max_batch=4, deadline_ms=1.0))
    b.observe_service(10.0)             # EWMA far beyond any deadline
    b.submit(_query(0))
    assert len(b.queue) == 1 and b.shed == 0


def test_deadline_needs_a_service_estimate():
    # before any batch has executed there is no EWMA — admit rather than
    # shed on a guess
    b = Batcher(BatcherConfig(max_batch=2, deadline_ms=0.001))
    for i in range(10):
        b.submit(_query(i))
    assert len(b.queue) == 10 and b.shed == 0


def test_queue_stays_bounded_under_overload():
    b = Batcher(BatcherConfig(max_batch=4, max_queue=16))
    admitted = shed = 0
    for i in range(100):
        try:
            b.submit(_query(i))
            admitted += 1
        except QueryShedError:
            shed += 1
        assert len(b.queue) <= 16
    assert admitted == 16 and shed == 84
    assert admitted + shed == 100       # every query accounted for


# ---------------------------------------------------------------------------
# degraded (warm-cache-only) serving at the PS level
# ---------------------------------------------------------------------------

def test_degraded_zero_fills_misses_and_measures_the_delta():
    tables = _tables()
    pats = _pats()
    idx0 = _batch(pats, 8, seed=0)
    ps = ParameterServer(tables, PSConfig(hot_rows=32, warm_slots=16),
                         trace=idx0)
    np.testing.assert_array_equal(ps.lookup(idx0), _gather(tables, idx0))

    assert ps.set_degraded(True) and ps.degraded()
    idx1 = _batch(pats, 8, seed=1)
    out = ps.lookup(idx1)
    ref = _gather(tables, idx1)
    hit = np.all(out == ref, axis=-1)
    zero = np.all(out == 0.0, axis=-1)
    assert np.all(hit | zero)           # every row exact or zero-filled
    assert zero[~hit].all() and zero.sum() > 0

    st = ps.stats()
    assert st["degraded_lookups"] == 1
    assert st["degraded_rows"] == int(np.count_nonzero(~hit))
    measured = float(np.linalg.norm((out - ref).astype(np.float64)))
    assert st["degraded_l2_delta"] == pytest.approx(measured, rel=1e-9)
    assert st["degraded_l2_delta"] > 0.0
    # the tier invariant survives degraded accounting
    assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
            == st["total_accesses"])

    # leaving the mode restores bit-exactness IMMEDIATELY: the warm tier
    # was never polluted with zeros
    assert ps.set_degraded(False) and not ps.degraded()
    np.testing.assert_array_equal(ps.lookup(idx1), ref)


def test_degraded_blocks_staging_until_restored():
    pats = _pats()
    idx0 = _batch(pats, 8, seed=0)
    ps = ParameterServer(_tables(), PSConfig(hot_rows=16, warm_slots=16,
                                             prefetch_depth=2), trace=idx0)
    assert ps.can_stage()
    ps.set_degraded(True)
    assert not ps.can_stage()
    assert not ps.stage(idx0)           # no new prefetch work while degraded
    ps.set_degraded(False)
    assert ps.can_stage()


def test_degraded_delta_monotone_in_cache_hit_rate():
    tables = _tables()
    pats = _pats()
    idx0 = _batch(pats, 16, seed=0)
    idx1 = _batch(pats, 16, seed=1)
    deltas = []
    for hot in (8, 64, ROWS):
        ps = ParameterServer(tables, PSConfig(hot_rows=hot, warm_slots=8),
                             trace=idx0)
        ps.set_degraded(True)
        ps.lookup(idx1)
        deltas.append(ps.stats()["degraded_l2_delta"])
    # more rows resident -> strictly less zero-filling -> smaller delta;
    # with every row hot the degraded answer is the exact answer
    assert deltas[0] > deltas[1] > deltas[2]
    assert deltas[2] == 0.0


# ---------------------------------------------------------------------------
# SLO escalation ladder (stub storage: pure controller logic)
# ---------------------------------------------------------------------------

class _StubStorage:
    """Minimal protocol surface the controller touches."""

    def __init__(self, depth=2, tunable=True, degradable=True):
        self._caps = StorageCapabilities(tunable=tunable,
                                         degradable=degradable)
        self.depth = depth
        self.is_degraded = False
        self.routing_calls = 0
        self.degrade_calls = []

    def capabilities(self):
        return self._caps

    def prefetch_depth(self):
        return self.depth

    def set_prefetch_depth(self, depth):
        self.depth = int(depth)
        return True

    def degraded(self):
        return self.is_degraded

    def set_degraded(self, on):
        self.is_degraded = bool(on)
        self.degrade_calls.append(bool(on))
        return True

    def update_routing(self):
        self.routing_calls += 1
        return None

    # AutoTuner surface (only used by the no-oscillation test)
    def __post_init__(self):
        pass

    def stats(self):
        return dict(self._counters)

    def take_prefetch_window_peak(self):
        return 0


def _controller(storage, stats=None, tuner=None, batcher=None, **cfg_kw):
    cfg_kw.setdefault("target_p99_ms", 10.0)
    cfg_kw.setdefault("window_queries", 32)
    cfg_kw.setdefault("check_every_batches", 1)
    stats = stats if stats is not None else types.SimpleNamespace(
        query_latencies_s=[])
    return SLOController(SLOConfig(**cfg_kw), storage, stats, tuner=tuner,
                         batcher=batcher), stats


def test_ladder_escalates_widen_then_degrade_then_recovers():
    store = _StubStorage(depth=2)
    ctl, stats = _controller(store, max_prefetch_depth=4)
    stats.query_latencies_s.extend([0.050] * 32)        # 50ms >> 10ms target
    ctl.step()
    assert ctl.level == 1 and store.depth == 3          # widen + route
    assert store.routing_calls == 1 and not store.is_degraded
    ctl.step()
    assert ctl.level == 2 and store.is_degraded         # degrade
    assert store.depth == 4
    ctl.step()                                          # already at the top
    assert ctl.level == 2 and store.depth == 4          # bounded widen
    assert ctl.breaches == 3
    assert ctl.degraded_batches >= 1

    # hysteresis: between recover_frac*target and target nothing moves
    stats.query_latencies_s[:] = [0.009] * 32           # 9ms: inside band
    ctl.step()
    assert ctl.level == 2 and store.is_degraded

    stats.query_latencies_s[:] = [0.002] * 32           # 2ms < 7ms floor
    ctl.step()
    assert ctl.level == 1 and not store.is_degraded     # exact again first
    ctl.step()
    assert ctl.level == 0 and store.depth == 2          # base depth restored
    assert [e["action"] for e in ctl.events] == [
        "widen", "degrade", "restore_exact", "recover"]


def test_ladder_shrink_rung_between_widen_and_degrade():
    """With min_batch > 0 and a batcher handle, the ladder halves the
    batch quantum (scaling the window) BEFORE degrading, and regrows the
    original batcher config on the way down."""
    store = _StubStorage(depth=2)
    batcher = Batcher(BatcherConfig(max_batch=16, max_wait_s=0.008))
    ctl, stats = _controller(store, max_prefetch_depth=4, min_batch=4,
                             batcher=batcher)
    stats.query_latencies_s.extend([0.050] * 32)        # 50ms >> 10ms
    ctl.step()
    assert ctl.level == 1 and not store.is_degraded     # widen first
    ctl.step()
    assert ctl.level == 2 and batcher.cfg.max_batch == 8
    assert batcher.cfg.max_wait_s == pytest.approx(0.004)
    assert not store.is_degraded                        # quality untouched
    ctl.step()
    assert batcher.cfg.max_batch == 4                   # halve to the floor
    assert ctl.level == 2 and not store.is_degraded
    ctl.step()                                          # floored: degrade
    assert ctl.level == 3 and store.is_degraded
    assert ctl.batch_shrinks == 2
    assert ctl.summary()["slo_batch_shrinks"] == 2

    # descent mirrors ascent: exact answers, then regrow, then recover
    stats.query_latencies_s[:] = [0.002] * 32
    ctl.step()
    assert ctl.level == 2 and not store.is_degraded
    assert batcher.cfg.max_batch == 4                   # still shrunken
    ctl.step()
    assert ctl.level == 1 and batcher.cfg.max_batch == 16
    assert batcher.cfg.max_wait_s == pytest.approx(0.008)
    ctl.step()
    assert ctl.level == 0 and store.depth == 2
    assert [e["action"] for e in ctl.events] == [
        "widen", "shrink", "shrink", "degrade",
        "restore_exact", "regrow", "recover"]


def test_shrink_rung_needs_both_min_batch_and_batcher():
    """min_batch alone (no batcher handle) leaves the PR-5 2-rung ladder:
    the degraded rung stays at level 2 and no shrink events appear."""
    store = _StubStorage()
    ctl, stats = _controller(store, min_batch=4)        # batcher=None
    stats.query_latencies_s.extend([0.050] * 32)
    ctl.step()
    ctl.step()
    assert ctl.level == 2 and store.is_degraded
    assert ctl.batch_shrinks == 0
    assert all(e["action"] != "shrink" for e in ctl.events)
    with pytest.raises(ValueError, match="min_batch"):
        SLOConfig(target_p99_ms=10.0, min_batch=-1)


def test_ladder_skips_degrade_on_incapable_backend():
    store = _StubStorage(degradable=False)
    ctl, stats = _controller(store)
    stats.query_latencies_s.extend([0.050] * 32)
    for _ in range(5):
        ctl.step()
    assert ctl.level == 1                               # never reaches 2
    assert store.degrade_calls == [] and not store.is_degraded
    assert ctl.breaches == 5                            # still measured


def test_controller_publishes_depth_ownership_to_tuner():
    store = _StubStorage()
    tuner = types.SimpleNamespace(depth_suspended=False)
    ctl, stats = _controller(store, tuner=tuner)
    stats.query_latencies_s.extend([0.050] * 32)
    ctl.step()
    assert ctl.engaged and tuner.depth_suspended
    stats.query_latencies_s[:] = [0.001] * 32
    ctl.step()
    assert not ctl.engaged and not tuner.depth_suspended


def test_windowed_p99_definition():
    assert windowed_p99_ms([], 8) is None
    lat = [0.001] * 992 + [0.100] * 8
    # window sees only the slow tail; the full series dilutes it away
    assert windowed_p99_ms(lat, 8) == pytest.approx(100.0)
    assert windowed_p99_ms(lat, 1000) < 50.0


def test_slo_config_validates():
    with pytest.raises(ValueError):
        SLOConfig(target_p99_ms=0.0)
    with pytest.raises(ValueError):
        SLOConfig(target_p99_ms=10.0, recover_frac=1.0)


# ---------------------------------------------------------------------------
# no tug-of-war with the PR 4 queue-depth auto-tuner (2k batches)
# ---------------------------------------------------------------------------

class _TunerStubStorage(_StubStorage):
    """Adds the counter surface `AutoTuner`'s depth leg reads. The fed
    signal always argues for NARROWING (perfect overlap, idle slots) —
    the exact opposite of the SLO controller's widening — so any batch
    where both controllers act on the depth shows up as a direction
    flip."""

    def __init__(self, depth=2):
        super().__init__(depth=depth)
        self.ready = 0

    def feed_batch(self):
        self.ready += 1                 # consumer always found it resolved

    def stats(self):
        return {"consume_ready": self.ready, "consume_waited": 0}


def test_slo_and_depth_tuner_never_fight_over_2k_batches():
    store = _TunerStubStorage(depth=4)
    tuner = AutoTuner(AutoTuneConfig(
        depth_every_batches=8,
        controller=QueueDepthController(min_depth=1, max_depth=8)), store)
    stats = types.SimpleNamespace(query_latencies_s=[])
    ctl, _ = _controller(store, stats=stats, tuner=tuner,
                         check_every_batches=4, window_queries=64,
                         max_prefetch_depth=8)

    # 10 cycles of (100 overloaded batches, 100 healthy batches)
    depth_trace, engaged_trace = [], []
    for batch in range(2000):
        overloaded = (batch // 100) % 2 == 0
        stats.query_latencies_s.append(0.050 if overloaded else 0.002)
        store.feed_batch()
        ctl.step()                      # session order: SLO first,
        tuner.step()                    # then the auto-tuner
        depth_trace.append(store.depth)
        engaged_trace.append(ctl.engaged)

    # 1. while the SLO controller is engaged it OWNS the depth: the tuner
    #    must not have moved it on any engaged batch
    engaged_batches = {i + 1 for i, e in enumerate(engaged_trace) if e}
    tuner_moves = [e for e in tuner.events if e["kind"] == "depth"]
    assert all(e["batch"] not in engaged_batches for e in tuner_moves)
    # 2. within any engaged stretch the depth is monotone non-decreasing
    #    (the SLO loop only widens)
    for i in range(1, 2000):
        if engaged_trace[i - 1] and engaged_trace[i]:
            assert depth_trace[i] >= depth_trace[i - 1]
    # 3. no oscillation: direction flips are bounded by the phase
    #    transitions of the workload itself, not proportional to batches
    moves = [b - a for a, b in zip(depth_trace, depth_trace[1:])
             if a != b]
    flips = sum(1 for x, y in zip(moves, moves[1:])
                if (x > 0) != (y > 0))
    assert flips <= 25                  # ~1 per phase edge; 2000 batches
    # 4. both controllers were actually live
    assert tuner_moves                  # tuner narrowed in healthy phases
    assert ctl.breaches > 0 and ctl.events


# ---------------------------------------------------------------------------
# session-level: flash-crowd replay stays bit-exact; degraded is measured
# ---------------------------------------------------------------------------

def _flash_session(slo):
    cfg = DLRMConfig(embedding=EmbeddingStageConfig(
        num_tables=TABLES, rows=ROWS, dim=16, pooling=POOL,
        storage="tiered"),
        bottom_mlp=(32, 16), top_mlp=(16, 1))
    model = DLRM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = make_traffic("steady", base_qps=100.0, num_tables=TABLES,
                       rows=ROWS, pooling=POOL, seed=0)
    trace = np.stack([q.indices for q in gen.queries(32)])
    model.ebc.storage.build(
        params, PSConfig(hot_rows=32, warm_slots=32, prefetch_depth=2),
        trace=trace)
    return ServingSession(
        model, params,
        batcher=BatcherConfig(max_batch=16, max_wait_s=0.002),
        slo=slo, clock=VirtualClock())


def test_non_degraded_answers_bit_exact_under_flash_load():
    # degrade=False: the ladder may widen/route/shed but every ANSWERED
    # query must still be bit-exact vs the dense gather
    sess = _flash_session(SLOConfig(target_p99_ms=8.0, degrade=False,
                                    shed_deadline_frac=0.5,
                                    check_every_batches=2,
                                    window_queries=64))
    try:
        tables = sess.storage.ps.cold.tables
        seen = []
        orig = sess.storage.ps.lookup

        def spy(indices):
            out = orig(indices)
            seen.append((np.array(indices), np.array(out)))
            return out

        sess.storage.ps.lookup = spy
        gen = make_traffic("flash", base_qps=2000.0, spike_qps=40000.0,
                           spike_start_s=0.05, spike_len_s=0.15,
                           num_tables=TABLES, rows=ROWS, pooling=POOL,
                           seed=1)
        rep = replay(sess, gen.queries(1500), window_queries=64)
        assert rep.shed > 0             # the spike genuinely overloaded it
        assert rep.served == rep.admitted > 0
        assert not sess.storage.degraded()
        assert rep.percentiles["slo_degraded_batches"] == 0
        assert seen
        for idx, out in seen:           # bit-identical, not just close
            np.testing.assert_array_equal(out, _gather(tables, idx))
    finally:
        sess.close()


def test_session_reports_degraded_counters_in_percentiles():
    sess = _flash_session(SLOConfig(target_p99_ms=50.0))
    try:
        assert sess.storage.capabilities().degradable
        assert sess.storage.set_degraded(True)
        gen = make_traffic("steady", base_qps=2000.0, num_tables=TABLES,
                           rows=ROWS, pooling=POOL, seed=2)
        rep = replay(sess, gen.queries(200), window_queries=64)
        pct = rep.percentiles
        assert pct["degraded_lookups"] > 0
        assert pct["degraded_rows"] > 0
        assert pct["degraded_l2_delta"] > 0.0
        assert rep.timeline[-1].degraded
    finally:
        sess.close()


def test_session_derives_shed_deadline_from_slo_target():
    sess = _flash_session(SLOConfig(target_p99_ms=20.0,
                                    shed_deadline_frac=0.5))
    try:
        assert sess.server.batcher.cfg.deadline_ms == pytest.approx(10.0)
        assert sess.slo is not None
        assert sess.percentiles() == {} or True     # smoke: no crash
    finally:
        sess.close()

    # frac 0 leaves the batcher un-armed (opt-out of the coupling)
    sess = _flash_session(SLOConfig(target_p99_ms=20.0,
                                    shed_deadline_frac=0.0))
    try:
        assert sess.server.batcher.cfg.deadline_ms == 0.0
    finally:
        sess.close()
