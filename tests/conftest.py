import importlib.util
import os
import subprocess
import sys

import pytest

# Property tests import `hypothesis`; fall back to the deterministic in-repo
# stub (tests/_stubs/) when the real library is not installed. conftest runs
# before test-module collection, so the path is ready in time.
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "_stubs"))

# NOTE (per instructions): XLA_FLAGS / host-device-count is deliberately NOT
# set here — unit tests see the real single CPU device. Multi-device tests run
# in subprocesses via `run_multidevice`.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(code: str, ndev: int = 8, timeout: int = 600):
    """Run a python snippet with N forced host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
