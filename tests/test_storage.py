"""EmbeddingStorage protocol, registry, backends, ServingSession facade.

Covers the PR-3 acceptance contract: all three registered backends
(`device`, `tiered`, `sharded`) are bit-exact against the dense gather
reference on the same trace; registry misuse (unknown name, double
registration, capability mismatch) raises clear errors; the sharded
backend merges per-shard stats into one report that preserves the counter
invariant; `ServingSession` reports `off_critical_frac`/cache stats for
any async-capable backend with no backend-specific serving code; and the
PR 1–2 shim surfaces (`build_parameter_server`, `InferenceServer(ps=...)`,
`EmbeddingBagCollection(ps=...)`) stay removed — the regression tests at
the bottom pin the replacements from the docs/serving.md migration table.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import storage as storage_pkg
from repro.core import (EmbeddingBagCollection, EmbeddingStageConfig,
                        make_pattern)
from repro.data import DLRMQueryStream
from repro.models.dlrm import DLRM, DLRMConfig
from repro.ps import ParameterServer, PSConfig
from repro.serving import (BatcherConfig, InferenceServer, Query,
                           ServingSession)
from repro.storage import (CapabilityError, DeviceStorage, EmbeddingStorage,
                           ShardedStorage, StorageCapabilities,
                           TieredStorage, UnknownBackendError,
                           require_capability)
from repro.storage.sharded import merge_shard_stats

ROWS, TABLES, DIM, POOL = 256, 4, 32, 6


def _pats(hotness="med_hot"):
    return [make_pattern(hotness, ROWS, seed=t) for t in range(TABLES)]


def _batch(pats, batch, seed):
    return np.stack([p.sample(batch, POOL, seed=seed * 100 + t)
                     for t, p in enumerate(pats)], axis=1).astype(np.int32)


def _stage_cfg(storage="device", **kw):
    return EmbeddingStageConfig(num_tables=TABLES, rows=ROWS, dim=DIM,
                                pooling=POOL, backend="xla",
                                storage=storage, **kw)


@pytest.fixture(scope="module")
def dense_ref():
    """Dense-gather reference collection + params (the bit-exact oracle)."""
    ebc = EmbeddingBagCollection(_stage_cfg("device"))
    params = ebc.init(jax.random.PRNGKey(0))
    return ebc, params


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_in_tree_backends():
    names = storage_pkg.available()
    assert {"device", "tiered", "sharded"} <= set(names)
    assert storage_pkg.resolve("sharded") is ShardedStorage


def test_unknown_backend_name_raises_with_available_list():
    with pytest.raises(UnknownBackendError, match="floppy"):
        storage_pkg.resolve("floppy")
    # surfaced through the collection constructor too, listing what exists
    with pytest.raises(ValueError, match="available.*device"):
        EmbeddingBagCollection(_stage_cfg("floppy"))


def test_double_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        @storage_pkg.register("device")
        class Impostor(DeviceStorage):
            pass
    # the original registration is untouched
    assert storage_pkg.resolve("device") is DeviceStorage


def test_out_of_tree_backend_registers_and_resolves():
    @storage_pkg.register("null_probe")
    class NullStorage(EmbeddingStorage):
        def capabilities(self):
            return StorageCapabilities()

        def lookup(self, params, indices, weights=None, *,
                   pre_remapped=False):
            b, t, _ = np.asarray(indices).shape
            return jnp.zeros((b, t, self.cfg.dim), self.cfg.jnp_dtype)

    try:
        ebc = EmbeddingBagCollection(_stage_cfg("null_probe"))
        assert ebc.storage.name == "null_probe"
        out = ebc.apply({}, jnp.zeros((2, TABLES, POOL), jnp.int32))
        assert out.shape == (2, TABLES, DIM)
        # protocol defaults: a minimal backend still satisfies the drivers
        assert ebc.storage.can_stage() is False
        assert ebc.storage.stage(np.zeros((1, TABLES, POOL))) is False
        assert ebc.storage.refresh() == {"replanned": False, "refreshes": 0}
        assert ebc.storage.stats() == {}
    finally:
        storage_pkg.unregister("null_probe")
    with pytest.raises(UnknownBackendError):
        storage_pkg.resolve("null_probe")


def test_capability_mismatch_raises_clear_error(dense_ref):
    ebc, _ = dense_ref
    with pytest.raises(CapabilityError, match="device.*async_prefetch"):
        require_capability(ebc.storage, "async_prefetch")
    with pytest.raises(ValueError, match="unknown capability"):
        require_capability(ebc.storage, "time_travel")
    # tiered built WITHOUT async prefetch: stageable but not async-capable
    tb = EmbeddingBagCollection(_stage_cfg("tiered"))
    tb.storage.build({"tables": np.zeros((TABLES, ROWS, DIM), np.float32)},
                     PSConfig(hot_rows=8, warm_slots=8))
    assert tb.storage.capabilities().stageable
    with pytest.raises(CapabilityError, match="async_prefetch"):
        require_capability(tb.storage, "async_prefetch")


# ---------------------------------------------------------------------------
# bit-exactness: every backend vs the dense gather reference, same trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,build_kw", [
    ("device", None),
    ("tiered", {}),
    ("sharded", {"num_shards": 2}),
    ("sharded", {"num_shards": 3}),     # uneven 4-table split: [2, 1, 1]
])
def test_backends_bit_exact_vs_dense(dense_ref, backend, build_kw):
    ebc0, params = dense_ref
    pats = _pats()
    trace = _batch(pats, 8, seed=99)
    ebc = EmbeddingBagCollection(_stage_cfg(backend))
    if build_kw is not None:
        ebc.storage.build(params,
                          PSConfig(hot_rows=32, warm_slots=32,
                                   async_prefetch=True, window_batches=4),
                          trace=trace, **build_kw)
    with ebc.storage:
        for seed in range(5):
            idx = _batch(pats, 8, seed=seed)
            if seed == 1:       # staged payloads must not change values
                ebc.storage.stage(_batch(pats, 8, seed=2))
            if seed == 3:       # neither must a mid-stream re-pin
                ebc.storage.refresh()
            got = np.asarray(ebc.apply(params, jnp.asarray(idx)))
            want = np.asarray(ebc0.apply(params, jnp.asarray(idx)))
            assert np.array_equal(got, want), (backend, seed)


def test_sharded_weighted_mean_bit_exact(dense_ref):
    _, params = dense_ref
    ebc0 = EmbeddingBagCollection(_stage_cfg("device", combine="mean"))
    ebc = EmbeddingBagCollection(_stage_cfg("sharded", combine="mean"))
    ebc.storage.build(params, PSConfig(hot_rows=16, warm_slots=16),
                      num_shards=2)
    idx = _batch(_pats(), 8, seed=0)
    w = np.random.default_rng(3).random((8, TABLES, POOL)).astype(np.float32)
    got = np.asarray(ebc.apply(params, jnp.asarray(idx), jnp.asarray(w)))
    want = np.asarray(ebc0.apply(params, jnp.asarray(idx), jnp.asarray(w)))
    assert np.array_equal(got, want)
    ebc.storage.close()


# ---------------------------------------------------------------------------
# sharded backend: partitioning, merged stats, capabilities
# ---------------------------------------------------------------------------

def test_sharded_partitions_cover_all_tables(dense_ref):
    _, params = dense_ref
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8),
                      num_shards=3)
    sls = ebc.storage.table_slices
    assert sls[0].start == 0 and sls[-1].stop == TABLES
    assert all(a.stop == b.start for a, b in zip(sls, sls[1:]))
    # shard count clamps to the table count
    ebc2 = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc2.storage.build(params, PSConfig(hot_rows=8), num_shards=64)
    assert ebc2.storage.num_shards == TABLES
    with pytest.raises(ValueError, match="num_shards"):
        ebc2.storage.build(params, PSConfig(hot_rows=8), num_shards=0)
    ebc.storage.close()
    ebc2.storage.close()


def test_sharded_merged_stats_preserve_invariant(dense_ref):
    _, params = dense_ref
    pats = _pats()
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    ebc.storage.build(params,
                      PSConfig(hot_rows=16, warm_slots=16,
                               window_batches=4),
                      trace=_batch(pats, 8, seed=99), num_shards=2)
    for seed in range(4):
        ebc.storage.stage(_batch(pats, 8, seed=seed + 1))
        ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=seed)))
    st = ebc.storage.stats()
    assert st["num_shards"] == 2
    assert st["total_accesses"] == 4 * 8 * TABLES * POOL
    assert (st["hot_hits"] + st["warm_hits"] + st["cold_misses"]
            == st["total_accesses"])
    assert 0.0 <= st["cache_hit_rate"] <= 1.0
    assert len(st["per_shard"]) == 2
    # merged counters really are the per-shard sums
    for key in ("total_accesses", "hot_hits", "prefetch_hits"):
        assert st[key] == sum(s[key] for s in st["per_shard"])
    # sharded refresh re-plans every shard in lockstep
    assert ebc.storage.refresh()["replanned"]
    assert all(ps.refreshes == 1 for ps in ebc.storage.shards)
    assert ebc.storage.stats()["refreshes"] == 1
    ebc.storage.close()


def test_merge_shard_stats_unit():
    a = {"total_accesses": 10, "hot_hits": 4, "warm_hits": 2,
         "cold_misses": 4, "prefetch_hits": 3, "prefetch_misses": 1,
         "off_critical_rows": 3, "max_queue_depth": 2, "refreshes": 1}
    b = {"total_accesses": 10, "hot_hits": 8, "warm_hits": 0,
         "cold_misses": 2, "prefetch_hits": 1, "prefetch_misses": 1,
         "off_critical_rows": 0, "max_queue_depth": 1, "refreshes": 1}
    m = merge_shard_stats([a, b])
    assert m["num_shards"] == 2
    assert m["total_accesses"] == 20 and m["hot_hits"] == 12
    assert m["cache_hit_rate"] == pytest.approx(14 / 20)
    assert m["off_critical_frac"] == pytest.approx(3 / 6)
    assert m["max_queue_depth"] == 2 and m["refreshes"] == 1


def test_sharded_serial_fanout_matches_parallel(dense_ref):
    """parallel=False (no shard pool) is an observable no-op."""
    _, params = dense_ref
    pats = _pats()
    outs = {}
    for parallel in (True, False):
        ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
        ebc.storage.build(params, PSConfig(hot_rows=16, warm_slots=16),
                          num_shards=2, parallel=parallel)
        assert (ebc.storage._pool is not None) == parallel
        outs[parallel] = np.asarray(
            ebc.apply(params, jnp.asarray(_batch(pats, 8, seed=0))))
        ebc.storage.close()
    assert np.array_equal(outs[True], outs[False])


@pytest.mark.parametrize("backend,build_kw", [
    ("tiered", {}), ("sharded", {"num_shards": 2})])
def test_staging_capabilities_drop_after_close(dense_ref, backend, build_kw):
    """A closed backend must not advertise staging it can no longer do
    (its async workers are joined); refresh/lookup capability semantics
    follow ParameterServer.close()."""
    _, params = dense_ref
    ebc = EmbeddingBagCollection(_stage_cfg(backend))
    ebc.storage.build(params, PSConfig(hot_rows=8, warm_slots=8,
                                       async_prefetch=True), **build_kw)
    assert ebc.storage.capabilities().async_prefetch
    ebc.storage.close()
    caps = ebc.storage.capabilities()
    assert not caps.stageable and not caps.async_prefetch
    assert ebc.storage.can_stage() is False


def test_sharded_requires_build_and_rejects_double_remap():
    ebc = EmbeddingBagCollection(_stage_cfg("sharded"))
    with pytest.raises(RuntimeError, match="build"):
        ebc.apply({}, jnp.zeros((2, TABLES, POOL), jnp.int32))
    with pytest.raises(ValueError, match="pinned_rows"):
        EmbeddingBagCollection(_stage_cfg("sharded", pinned_rows=8))


# ---------------------------------------------------------------------------
# ServingSession: generic overlap reporting, no backend-specific code
# ---------------------------------------------------------------------------

def _session_model(storage):
    emb = _stage_cfg(storage)
    model = DLRM(DLRMConfig(embedding=emb, bottom_mlp=(64, DIM),
                            top_mlp=(32, 1)))
    params = model.init(jax.random.PRNGKey(0))
    stream = DLRMQueryStream(num_tables=TABLES, rows=ROWS, pooling=POOL,
                             batch_size=8, hotness="med_hot", seed=1)
    return model, params, stream


@pytest.mark.parametrize("backend,build_kw", [
    ("tiered", {}),
    ("sharded", {"num_shards": 2}),
])
def test_session_reports_overlap_stats_for_async_backends(backend, build_kw):
    model, params, stream = _session_model(backend)
    model.ebc.storage.build(
        params, PSConfig(hot_rows=32, warm_slots=32, window_batches=4,
                         async_prefetch=True),
        trace=stream.sample_trace(2), **build_kw)
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6, refresh_every_batches=2,
                        async_refresh=True) as sess:
        for b in range(6):
            batch = stream.next_batch()
            sess.submit_batch(batch.dense, batch.indices, qid0=b * 8)
            if b >= 1:
                sess.poll()
        sess.drain()
        pct = sess.percentiles()
    assert pct["served"] == 48
    assert pct["refreshes"] >= 1
    # the acceptance contract: overlap + cache stats surface through the
    # generic loop for ANY async-capable backend
    for key in ("off_critical_frac", "cache_hit_rate", "hot_hit_rate",
                "max_queue_depth", "consume_overlap_frac"):
        assert key in pct, (backend, key, sorted(pct))
    assert pct["max_queue_depth"] >= 1       # staging actually queued


def test_session_device_backend_serves_without_storage_keys():
    model, params, stream = _session_model("device")
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6) as sess:
        batch = stream.next_batch()
        sess.submit_batch(batch.dense, batch.indices)
        sess.drain()
        pct = sess.percentiles()
    assert pct["served"] == 8
    assert "cache_hit_rate" not in pct and "off_critical_frac" not in pct


def test_session_rejects_async_refresh_on_device_backend():
    model, params, _ = _session_model("device")
    with pytest.raises(CapabilityError, match="refreshable"):
        ServingSession(model, params, batcher=BatcherConfig(max_batch=8),
                       async_refresh=True, warmup=False)
    with pytest.raises(CapabilityError, match="refreshable"):
        ServingSession(model, params, batcher=BatcherConfig(max_batch=8),
                       refresh_every_batches=4, warmup=False)


def test_session_matches_dense_scores_tiered():
    """Session-served scores equal the raw dense forward on the same
    queries (embedding stage bit-exact; MLP halves to float32 noise)."""
    model, params, stream = _session_model("tiered")
    model.ebc.storage.build(params, PSConfig(hot_rows=32, warm_slots=32),
                            trace=stream.sample_trace(2))
    captured = {}
    with ServingSession(model, params,
                        batcher=BatcherConfig(max_batch=8, max_wait_s=0.0),
                        sla_ms=1e6) as sess:
        stream0 = DLRMQueryStream(num_tables=TABLES, rows=ROWS, pooling=POOL,
                                  batch_size=8, hotness="med_hot", seed=1)
        b = stream0.next_batch()
        captured["scores"] = np.asarray(sess._forward(b.dense, b.indices))
    emb0 = _stage_cfg("device")
    model0 = DLRM(DLRMConfig(embedding=emb0, bottom_mlp=(64, DIM),
                             top_mlp=(32, 1)))
    want = model0.forward(params, jnp.asarray(b.dense),
                          jnp.asarray(b.indices))
    np.testing.assert_allclose(captured["scores"], np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# shim removal (the PR 1-2 `ps=` / build_parameter_server surfaces are gone)
# ---------------------------------------------------------------------------

def test_build_parameter_server_shim_removed():
    """The PR-3 deprecation shims were removed: `storage.build()` is the
    only construction path (replacements in the docs/serving.md table)."""
    assert not hasattr(EmbeddingBagCollection, "build_parameter_server")
    with pytest.raises(TypeError):
        EmbeddingBagCollection(_stage_cfg("tiered"), ps=object())
    # the replacement path serves bit-exact against the dense reference
    ebc = EmbeddingBagCollection(_stage_cfg("tiered"))
    params = ebc.init(jax.random.PRNGKey(0))
    ebc.storage.build(params, PSConfig(hot_rows=32, warm_slots=32))
    idx = jnp.asarray(_batch(_pats(), 4, seed=0))
    ref = EmbeddingBagCollection(_stage_cfg("device"))
    assert np.array_equal(np.asarray(ebc.apply(params, idx)),
                          np.asarray(ref.apply(params, idx)))
    ebc.storage.close()


def test_inference_server_ps_kwarg_removed_adopt_replaces_it():
    rng = np.random.default_rng(0)
    tables = rng.normal(size=(TABLES, ROWS, DIM)).astype(np.float32)
    ps = ParameterServer(tables, PSConfig(hot_rows=16, warm_slots=16,
                                          window_batches=4))

    def fwd(dense, idx):
        ps.lookup(idx)
        return np.zeros(len(dense), np.float32)

    with pytest.raises(TypeError):
        InferenceServer(fwd, BatcherConfig(), ps=ps)
    # replacement: adopt the raw server into the storage protocol
    srv = InferenceServer(fwd, BatcherConfig(max_batch=4, max_wait_s=0.0),
                          sla_ms=1e6, storage=TieredStorage.adopt(ps),
                          refresh_every_batches=1)
    assert not hasattr(srv, "ps")            # legacy accessor gone too
    idx = _batch(_pats(), 4, seed=0)
    for q in range(4):
        srv.submit(Query(qid=q, dense=np.zeros(2, np.float32),
                         indices=idx[q]))
    srv.drain(timeout_s=1.0)
    assert srv.stats.served == 4
    assert ps.refreshes == 1                 # generic driver still re-pins
    assert srv.stats.ps_stats["cache_hit_rate"] >= 0.0
    ps.close()


def test_ebc_ps_accessors_removed():
    ebc = EmbeddingBagCollection(_stage_cfg("tiered"))
    assert not hasattr(ebc, "ps")            # property deleted with the shim
    params = ebc.init(jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError, match="storage.build"):
        ebc.apply(params, jnp.asarray(_batch(_pats(), 2, seed=1)))
